//! The hierarchical parameter store (§2.1): unifies the SSD tier and the
//! CPU cache behind per-layer *fused* sparse blocks.
//!
//! Each decoder layer's expert tensors (w1,b1,w2,b2) plus their optimizer
//! moments are packed into three contiguous records:
//! `layer{i}.sparse.p|m|v` — one fused buffer per state kind, matching
//! the paper's "parameter management unit" (fused slices, re-split by
//! recorded index; the split metadata comes from the AOT manifest).
//!
//! The store is plain data (Send) so the 2D-prefetch scheduler can own it
//! on a background thread.

use anyhow::{bail, Result};

use super::cpu_cache::{CacheConfig, CpuCache};
use super::ssd_store::SsdStore;
use crate::runtime::ParamSpec;

/// One layer's sparse state, fused.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseBlock {
    pub layer: usize,
    /// Fused parameter values.
    pub p: Vec<f32>,
    /// Fused Adam momentum (empty when fetched for forward-only).
    pub m: Vec<f32>,
    /// Fused Adam variance (empty when fetched for forward-only).
    pub v: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct StoreConfig {
    pub cache: CacheConfig,
    /// Fetch optimizer moments alongside parameters.
    pub with_moments: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { cache: CacheConfig::default(), with_moments: true }
    }
}

pub struct HierarchicalStore {
    ssd: SsdStore,
    cache: CpuCache,
    cfg: StoreConfig,
    n_layers: usize,
    /// Elements per fused sparse block (one layer).
    block_len: usize,
    /// (name, numel) split metadata per layer, from the manifest.
    layout: Vec<(String, usize)>,
}

fn key(layer: usize, kind: &str) -> String {
    format!("layer{}.sparse.{}", layer, kind)
}

impl HierarchicalStore {
    /// Build from the manifest's parameter layout. `params` is the flat
    /// layout; sparse entries are grouped by layer.
    pub fn new(
        ssd: SsdStore,
        cfg: StoreConfig,
        params: &[ParamSpec],
        n_layers: usize,
    ) -> Result<HierarchicalStore> {
        let layer0: Vec<(String, usize)> = params
            .iter()
            .filter(|p| p.sparse && p.layer() == Some(0))
            .map(|p| (p.name.trim_start_matches("layer0.").to_string(), p.numel))
            .collect();
        if layer0.is_empty() {
            bail!("no sparse parameters in layout");
        }
        let block_len = layer0.iter().map(|(_, n)| n).sum();
        Ok(HierarchicalStore {
            ssd,
            cache: CpuCache::new(cfg.cache.clone()),
            cfg,
            n_layers,
            block_len,
            layout: layer0,
        })
    }

    pub fn block_len(&self) -> usize {
        self.block_len
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Per-layer split metadata (tensor name within the layer, numel).
    pub fn layout(&self) -> &[(String, usize)] {
        &self.layout
    }

    /// Seed the SSD tier with initial states for every layer.
    pub fn initialize(
        &mut self,
        mut init_p: impl FnMut(usize) -> Vec<f32>,
    ) -> Result<()> {
        for l in 0..self.n_layers {
            let p = init_p(l);
            assert_eq!(p.len(), self.block_len, "init block len");
            let zeros = vec![0.0f32; self.block_len];
            self.ssd.write(&key(l, "p"), &p)?;
            self.ssd.write(&key(l, "m"), &zeros)?;
            self.ssd.write(&key(l, "v"), &zeros)?;
        }
        Ok(())
    }

    fn fetch_kind(&mut self, layer: usize, kind: &str) -> Result<Vec<f32>> {
        let k = key(layer, kind);
        if let Some(data) = self.cache.get(&k) {
            return Ok(data.to_vec());
        }
        let data = self.ssd.read(&k)?;
        for ev in self.cache.insert(&k, data.clone(), false) {
            if ev.dirty {
                self.ssd.write(&ev.key, &ev.data)?;
            }
        }
        Ok(data)
    }

    /// Algorithm-1 `SparseSchedule`: fetch one layer's sparse block
    /// through the CPU cache (SSD on miss, evict+writeback when full).
    pub fn fetch(&mut self, layer: usize) -> Result<SparseBlock> {
        let p = self.fetch_kind(layer, "p")?;
        let (m, v) = if self.cfg.with_moments {
            (self.fetch_kind(layer, "m")?, self.fetch_kind(layer, "v")?)
        } else {
            (Vec::new(), Vec::new())
        };
        Ok(SparseBlock { layer, p, m, v })
    }

    /// Write an updated block back (dirty in cache; SSD write deferred to
    /// eviction or flush — this is what bounds SSD erase cycles).
    pub fn update(&mut self, block: SparseBlock) -> Result<()> {
        let kinds: [(&str, &Vec<f32>); 3] =
            [("p", &block.p), ("m", &block.m), ("v", &block.v)];
        for (kind, data) in kinds {
            if data.is_empty() {
                continue;
            }
            let k = key(block.layer, kind);
            if !self.cache.update(&k, data.clone()) {
                // Not cached (evicted since fetch): insert dirty.
                for ev in self.cache.insert(&k, data.clone(), true) {
                    if ev.dirty {
                        self.ssd.write(&ev.key, &ev.data)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// End-of-step housekeeping (decay of hit counters).
    pub fn end_step(&mut self) {
        self.cache.end_step();
    }

    /// Flush all dirty cache state to SSD (checkpoint / shutdown).
    pub fn flush(&mut self) -> Result<()> {
        for ev in self.cache.drain() {
            if ev.dirty {
                self.ssd.write(&ev.key, &ev.data)?;
            }
        }
        Ok(())
    }

    pub fn cache_stats(&self) -> super::cpu_cache::CacheStats {
        self.cache.stats()
    }

    pub fn ssd_stats(&self) -> super::tier::TierStats {
        self.ssd.stats()
    }

    pub fn ssd_total_erases(&self) -> u64 {
        self.ssd.total_erases()
    }

    /// Read a block directly from SSD bypassing the cache (verification).
    pub fn read_ssd_direct(&mut self, layer: usize) -> Result<Vec<f32>> {
        self.ssd.read(&key(layer, "p"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cpu_cache::CachePolicy;
    use crate::storage::ssd_store::SsdStore;

    fn specs(n_layers: usize) -> Vec<ParamSpec> {
        let mut v = Vec::new();
        for l in 0..n_layers {
            v.push(ParamSpec { name: format!("layer{}.wq", l), shape: vec![4, 4], sparse: false, numel: 16 });
            v.push(ParamSpec { name: format!("layer{}.w1", l), shape: vec![2, 4, 8], sparse: true, numel: 64 });
            v.push(ParamSpec { name: format!("layer{}.b1", l), shape: vec![2, 8], sparse: true, numel: 16 });
        }
        v
    }

    fn store(cache_blocks: usize, n_layers: usize) -> HierarchicalStore {
        let cfg = StoreConfig {
            cache: CacheConfig {
                capacity_bytes: cache_blocks * 80 * 4,
                policy: CachePolicy::Alg1,
                hit_threshold: 1.0,
                beta: 0.5,
                decay_every: 8,
            },
            with_moments: true,
        };
        let mut s =
            HierarchicalStore::new(SsdStore::memory_backed(), cfg, &specs(n_layers), n_layers)
                .unwrap();
        s.initialize(|l| vec![l as f32; 80]).unwrap();
        s
    }

    #[test]
    fn block_len_from_layout() {
        let s = store(4, 3);
        assert_eq!(s.block_len(), 80);
        assert_eq!(s.layout().len(), 2);
        assert_eq!(s.layout()[0], ("w1".to_string(), 64));
    }

    #[test]
    fn fetch_roundtrip_and_cache_hit() {
        let mut s = store(8, 3);
        let b = s.fetch(1).unwrap();
        assert_eq!(b.p, vec![1.0; 80]);
        assert_eq!(b.m, vec![0.0; 80]);
        let misses0 = s.cache_stats().misses;
        let _ = s.fetch(1).unwrap(); // now cached
        assert_eq!(s.cache_stats().misses, misses0);
        assert!(s.cache_stats().hits >= 3);
    }

    #[test]
    fn update_is_writeback_not_writethrough() {
        let mut s = store(16, 2);
        let mut b = s.fetch(0).unwrap();
        b.p = vec![42.0; 80];
        let erases_before = s.ssd_total_erases();
        s.update(b).unwrap();
        // No SSD write yet (dirty in cache).
        assert_eq!(s.ssd_total_erases(), erases_before);
        s.flush().unwrap();
        assert!(s.ssd_total_erases() > erases_before);
        assert_eq!(s.read_ssd_direct(0).unwrap(), vec![42.0; 80]);
    }

    #[test]
    fn eviction_pressure_writes_back_dirty_blocks() {
        // cache of 2 blocks, 3 layers × 3 kinds → heavy eviction traffic
        let mut s = store(2, 3);
        for l in 0..3 {
            let mut b = s.fetch(l).unwrap();
            b.p = vec![100.0 + l as f32; 80];
            s.update(b).unwrap();
            s.end_step();
        }
        s.flush().unwrap();
        for l in 0..3 {
            assert_eq!(s.read_ssd_direct(l).unwrap(), vec![100.0 + l as f32; 80], "layer {}", l);
        }
    }

    #[test]
    fn forward_only_fetch_skips_moments() {
        let cfg = StoreConfig {
            cache: CacheConfig::default(),
            with_moments: false,
        };
        let mut s =
            HierarchicalStore::new(SsdStore::memory_backed(), cfg, &specs(2), 2).unwrap();
        s.initialize(|_| vec![1.0; 80]).unwrap();
        let b = s.fetch(0).unwrap();
        assert!(b.m.is_empty() && b.v.is_empty());
        assert_eq!(b.p.len(), 80);
    }
}
