//! Hierarchical storage (paper §2.1): parameter states split by
//! activation behaviour — *dense* states live on the device tier,
//! *sparse* (expert) states live on the SSD tier with a CPU cache in
//! between, managed by the Algorithm-1 LFU policy. Records are
//! **(layer, expert)-granular** so the 2D prefetch scheduler can stream
//! exactly the routed expert subset; the hot-expert set is pinned in the
//! CPU cache.
//!
//! All types here are plain data (Send) — PJRT never appears below the
//! trainer, so the sparse lane can run on a background prefetch thread.

pub mod tier;
pub mod ssd_store;
pub mod cpu_cache;
pub mod param_store;

pub use cpu_cache::{CacheConfig, CachePolicy, CpuCache};
pub use param_store::{HierarchicalStore, SparseBlock, SparseLayout, StoreConfig};
pub use ssd_store::{SsdBackend, SsdStore};
pub use tier::{MemoryFootprint, Tier, TierStats};
