//! Storage tiers and the paper's §2.1 per-tier memory formulas.

use crate::config::ModelConfig;
use crate::util::human_bytes;

/// The three storage tiers of the hierarchical store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Device HBM (our substrate: PJRT host buffers owned by the worker).
    Gpu,
    /// Host DRAM cache.
    Cpu,
    /// NVMe SSD / Optane PMem (file- or memory-backed here).
    Ssd,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Gpu => "gpu",
            Tier::Cpu => "cpu",
            Tier::Ssd => "ssd",
        }
    }
}

/// Byte-traffic accounting per tier boundary.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierStats {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl TierStats {
    pub fn record_read(&mut self, bytes: usize) {
        self.reads += 1;
        self.bytes_read += bytes as u64;
    }

    pub fn record_write(&mut self, bytes: usize) {
        self.writes += 1;
        self.bytes_written += bytes as u64;
    }

    pub fn merge(&mut self, o: &TierStats) {
        self.reads += o.reads;
        self.writes += o.writes;
        self.bytes_read += o.bytes_read;
        self.bytes_written += o.bytes_written;
    }
}

/// Paper §2.1 memory footprint per device, in bytes, for mixed-precision
/// ADAM states:
///
/// - GPU: dense states `16·D` (fp16 param + fp16 grad + fp32 master +
///   fp32 momentum + fp32 variance = 2+2+4+4+4) plus in-flight sparse
///   working set `4·α·S/L` (fp16 param + fp16 grad of the active layers).
/// - CPU cache: `16·α·S` (full states of cached hot experts).
/// - SSD: `12·S` (fp32 master + momentum + variance of every expert).
///
/// `alpha` is the activation probability of a sparse parameter; `n_devices`
/// shards S and D.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryFootprint {
    pub gpu_bytes: f64,
    pub cpu_bytes: f64,
    pub ssd_bytes: f64,
}

impl MemoryFootprint {
    pub fn of(model: &ModelConfig, alpha: f64, n_devices: usize) -> MemoryFootprint {
        let n = n_devices.max(1) as f64;
        let d = model.dense_params() as f64 / n;
        let s = model.sparse_params() as f64 / n;
        let l = model.n_layers.max(1) as f64;
        MemoryFootprint {
            gpu_bytes: 16.0 * d + 4.0 * alpha * s / l,
            cpu_bytes: 16.0 * alpha * s,
            ssd_bytes: 12.0 * s,
        }
    }

    /// DeepSpeed-style (no hierarchical split): all states on GPU,
    /// ZeRO-3 sharded. 16 bytes/param + activation/fragmentation slack.
    pub fn resident(model: &ModelConfig, n_devices: usize) -> MemoryFootprint {
        let n = n_devices.max(1) as f64;
        let p = model.param_counts().total as f64 / n;
        MemoryFootprint { gpu_bytes: 16.0 * p, cpu_bytes: 0.0, ssd_bytes: 0.0 }
    }

    pub fn gpu_gb(&self) -> f64 {
        self.gpu_bytes / (1u64 << 30) as f64
    }

    pub fn describe(&self) -> String {
        format!(
            "gpu={} cpu={} ssd={}",
            human_bytes(self.gpu_bytes as u64),
            human_bytes(self.cpu_bytes as u64),
            human_bytes(self.ssd_bytes as u64)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{local_preset, table1_model};

    #[test]
    fn tier_traffic_accounting() {
        let mut s = TierStats::default();
        s.record_read(100);
        s.record_write(50);
        s.record_read(10);
        assert_eq!(s.reads, 2);
        assert_eq!(s.bytes_read, 110);
        let mut t = TierStats::default();
        t.merge(&s);
        assert_eq!(t.bytes_written, 50);
    }

    #[test]
    fn hierarchical_beats_resident_gpu_footprint() {
        // The entire point of §2.1: offloading sparse states shrinks GPU
        // memory by roughly the sparse fraction.
        let m = table1_model(64, 64);
        let res = MemoryFootprint::resident(&m, 64);
        let hier = MemoryFootprint::of(&m, 0.3, 64);
        assert!(hier.gpu_bytes < 0.25 * res.gpu_bytes,
                "hier {} vs res {}", hier.describe(), res.describe());
        assert!(hier.ssd_bytes > hier.cpu_bytes);
    }

    #[test]
    fn alpha_scales_cpu_cache() {
        let m = local_preset("base");
        let lo = MemoryFootprint::of(&m, 0.1, 1);
        let hi = MemoryFootprint::of(&m, 0.9, 1);
        assert!(hi.cpu_bytes > 8.0 * lo.cpu_bytes);
        assert_eq!(lo.ssd_bytes, hi.ssd_bytes); // SSD holds everything regardless
    }
}
