//! SSD-tier key/value store for sparse parameter states.
//!
//! Two backends, matching the paper's two storage media (§2.1):
//!
//! - [`SsdBackend::File`] — one file per record under a directory (NVMe
//!   SSD model). Records are raw little-endian f32. Write (erase) counts
//!   are tracked per key because "SSDs have a limited lifetime number of
//!   writes" is one of the paper's stated motivations.
//! - [`SsdBackend::Memory`] — byte-addressable in-memory store (the
//!   Optane PMem AppDirect/FSDAX substitution): same API, no filesystem.
//!
//! Optional throttling (`bandwidth`, `latency`) lets benches reproduce
//! NVMe-vs-PMem behaviour on this machine's substrate.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::tier::TierStats;

#[derive(Debug, Clone)]
pub enum SsdBackend {
    File { dir: PathBuf },
    Memory,
}

/// Simulated media performance; `None` = run at host speed.
#[derive(Debug, Clone, Copy, Default)]
pub struct MediaPerf {
    /// Bytes/second cap.
    pub bandwidth: Option<f64>,
    /// Fixed per-op latency.
    pub latency: Option<Duration>,
}

pub struct SsdStore {
    backend: SsdBackend,
    mem: HashMap<String, Vec<f32>>,
    perf: MediaPerf,
    stats: TierStats,
    erase_counts: HashMap<String, u64>,
}

impl SsdStore {
    pub fn file_backed(dir: PathBuf) -> Result<SsdStore> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating ssd store dir {}", dir.display()))?;
        Ok(SsdStore {
            backend: SsdBackend::File { dir },
            mem: HashMap::new(),
            perf: MediaPerf::default(),
            stats: TierStats::default(),
            erase_counts: HashMap::new(),
        })
    }

    /// Optane-PMem-style byte-addressable store.
    pub fn memory_backed() -> SsdStore {
        SsdStore {
            backend: SsdBackend::Memory,
            mem: HashMap::new(),
            perf: MediaPerf::default(),
            stats: TierStats::default(),
            erase_counts: HashMap::new(),
        }
    }

    pub fn with_perf(mut self, perf: MediaPerf) -> SsdStore {
        self.perf = perf;
        self
    }

    fn throttle(&self, bytes: usize) {
        if let Some(lat) = self.perf.latency {
            spin_sleep(lat);
        }
        if let Some(bw) = self.perf.bandwidth {
            spin_sleep(Duration::from_secs_f64(bytes as f64 / bw));
        }
    }

    fn key_path(dir: &std::path::Path, key: &str) -> PathBuf {
        // keys contain dots but no path separators; keep them readable.
        dir.join(format!("{}.bin", key.replace('/', "_")))
    }

    /// Write (or overwrite) a record.
    pub fn write(&mut self, key: &str, data: &[f32]) -> Result<()> {
        let bytes = data.len() * 4;
        self.throttle(bytes);
        *self.erase_counts.entry(key.to_string()).or_insert(0) += 1;
        self.stats.record_write(bytes);
        match &self.backend {
            SsdBackend::Memory => {
                self.mem.insert(key.to_string(), data.to_vec());
            }
            SsdBackend::File { dir } => {
                let path = Self::key_path(dir, key);
                let mut f = std::fs::File::create(&path)
                    .with_context(|| format!("creating {}", path.display()))?;
                // Safe little-endian serialization.
                let raw: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, bytes)
                };
                f.write_all(raw)?;
            }
        }
        Ok(())
    }

    /// Read a record fully.
    pub fn read(&mut self, key: &str) -> Result<Vec<f32>> {
        let out = match &self.backend {
            SsdBackend::Memory => self
                .mem
                .get(key)
                .cloned()
                .with_context(|| format!("ssd record '{}' missing", key))?,
            SsdBackend::File { dir } => {
                let path = Self::key_path(dir, key);
                let mut f = std::fs::File::open(&path)
                    .with_context(|| format!("ssd record '{}' missing", key))?;
                let len = f.metadata()?.len() as usize;
                if len % 4 != 0 {
                    bail!("corrupt record '{}': {} bytes", key, len);
                }
                let mut raw = vec![0u8; len];
                f.read_exact(&mut raw)?;
                let mut out = vec![0f32; len / 4];
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        raw.as_ptr(),
                        out.as_mut_ptr() as *mut u8,
                        len,
                    );
                }
                out
            }
        };
        self.throttle(out.len() * 4);
        self.stats.record_read(out.len() * 4);
        Ok(out)
    }

    pub fn contains(&self, key: &str) -> bool {
        match &self.backend {
            SsdBackend::Memory => self.mem.contains_key(key),
            SsdBackend::File { dir } => Self::key_path(dir, key).exists(),
        }
    }

    /// Delete a record. Missing keys are not an error — the checkpoint
    /// garbage collector must be idempotent across interrupted runs.
    pub fn remove(&mut self, key: &str) -> Result<()> {
        match &self.backend {
            SsdBackend::Memory => {
                self.mem.remove(key);
            }
            SsdBackend::File { dir } => {
                let path = Self::key_path(dir, key);
                if path.exists() {
                    std::fs::remove_file(&path)
                        .with_context(|| format!("removing {}", path.display()))?;
                }
            }
        }
        Ok(())
    }

    /// Keys currently present, sorted. The file backend reports the
    /// on-disk (separator-mangled) key form; checkpoint keys contain no
    /// path separators, so for them the two forms coincide.
    pub fn keys(&self) -> Vec<String> {
        let mut v: Vec<String> = match &self.backend {
            SsdBackend::Memory => self.mem.keys().cloned().collect(),
            SsdBackend::File { dir } => std::fs::read_dir(dir)
                .map(|rd| {
                    rd.filter_map(|e| e.ok())
                        .filter_map(|e| {
                            e.file_name()
                                .to_str()
                                .and_then(|n| n.strip_suffix(".bin"))
                                .map(String::from)
                        })
                        .collect()
                })
                .unwrap_or_default(),
        };
        v.sort();
        v
    }

    pub fn stats(&self) -> TierStats {
        self.stats
    }

    /// Total write (erase-cycle) count per key — the SSD-wear metric the
    /// paper's LFU writeback policy is designed to minimize.
    pub fn erase_count(&self, key: &str) -> u64 {
        self.erase_counts.get(key).copied().unwrap_or(0)
    }

    pub fn total_erases(&self) -> u64 {
        self.erase_counts.values().sum()
    }
}

/// Sleep that stays accurate for sub-millisecond simulated latencies.
fn spin_sleep(d: Duration) {
    if d > Duration::from_millis(2) {
        std::thread::sleep(d);
    } else {
        let t0 = Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(store: &mut SsdStore) {
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        store.write("layer0.sparse.p", &data).unwrap();
        assert!(store.contains("layer0.sparse.p"));
        let back = store.read("layer0.sparse.p").unwrap();
        assert_eq!(back, data);
        assert!(!store.contains("nope"));
        assert!(store.read("nope").is_err());
    }

    #[test]
    fn memory_backend_roundtrip() {
        let mut s = SsdStore::memory_backed();
        roundtrip(&mut s);
        assert_eq!(s.stats().reads, 1);
        assert_eq!(s.stats().bytes_written, 4000);
    }

    #[test]
    fn file_backend_roundtrip() {
        let dir = std::env::temp_dir().join(format!("semoe_ssd_test_{}", std::process::id()));
        let mut s = SsdStore::file_backed(dir.clone()).unwrap();
        roundtrip(&mut s);
        drop(s);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn erase_counts_accumulate() {
        let mut s = SsdStore::memory_backed();
        for _ in 0..5 {
            s.write("k", &[1.0]).unwrap();
        }
        s.write("other", &[2.0]).unwrap();
        assert_eq!(s.erase_count("k"), 5);
        assert_eq!(s.total_erases(), 6);
    }

    #[test]
    fn remove_and_keys_both_backends() {
        let dir = std::env::temp_dir().join(format!("semoe_ssd_rm_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for mut s in [SsdStore::memory_backed(), SsdStore::file_backed(dir.clone()).unwrap()] {
            s.write("layer0.expert0.s1", &[1.0]).unwrap();
            s.write("layer0.expert1.s1", &[2.0]).unwrap();
            assert_eq!(s.keys(), vec!["layer0.expert0.s1", "layer0.expert1.s1"]);
            s.remove("layer0.expert0.s1").unwrap();
            s.remove("layer0.expert0.s1").unwrap(); // idempotent
            assert!(!s.contains("layer0.expert0.s1"));
            assert_eq!(s.keys(), vec!["layer0.expert1.s1"]);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn throttling_slows_io() {
        let mut s = SsdStore::memory_backed().with_perf(MediaPerf {
            bandwidth: Some(1e6), // 1 MB/s
            latency: None,
        });
        let data = vec![0f32; 25_000]; // 100 KB -> 100 ms
        let t0 = Instant::now();
        s.write("k", &data).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(80));
    }
}
