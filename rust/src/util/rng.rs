//! Deterministic PRNG (splitmix64 core + PCG-style output) used by the
//! synthetic data generator, the simulator's workload draws, and the
//! in-tree property-test harness. No external `rand` crate in the build
//! environment, and determinism across runs is a feature: bench rows are
//! reproducible bit-for-bit.

/// Splitmix64-based generator. Copy-cheap; `split()` derives independent
/// streams for parallel workers.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (for per-worker determinism).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with mean 1/lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (s=0 → uniform).
    /// Used for the synthetic token corpus and skewed expert popularity.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.below(n);
        }
        // Inverse-CDF on the truncated harmonic sum (cached would be faster;
        // callers needing speed use ZipfTable).
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.next_f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// Precomputed Zipf sampler (alias-free inverse CDF table) for hot loops.
#[derive(Debug, Clone)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap_or(&1.0);
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(7);
        let mut s1 = r.split(1);
        let mut s2 = r.split(2);
        let overlap = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(overlap < 2);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn zipf_is_skewed_and_table_matches() {
        let mut r = Rng::new(5);
        let table = ZipfTable::new(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[table.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[50]);
        // rank-0 mass for s=1.1, n=100 is ~19%
        let p0 = counts[0] as f64 / 20_000.0;
        assert!(p0 > 0.12 && p0 < 0.30, "p0 {}", p0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
