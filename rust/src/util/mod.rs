//! In-tree substrates: JSON, CLI parsing, PRNG, statistics, logging,
//! human-readable byte formatting.
//!
//! The offline build environment vendors only the crates required by the
//! `xla` PJRT bindings (no serde/clap/criterion/rand), so these utilities
//! are first-class, fully-tested subsystems of the repo rather than
//! third-party dependencies.

pub mod json;
pub mod cli;
pub mod rng;
pub mod stats;
pub mod logging;
pub mod bytes;
pub mod sha256;

pub use bytes::{human_bytes, human_count, human_duration};
pub use json::Json;
pub use rng::Rng;
