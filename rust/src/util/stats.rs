//! Streaming statistics + percentile summaries for benches and serving
//! latency reports.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Sample set with exact percentiles. Unbounded by default (fine for
/// bench sizes); [`bounded`](Self::bounded) switches to reservoir
/// sampling (Algorithm R) for long-running accumulators like serving
/// latency, capping memory while keeping percentiles representative.
#[derive(Debug, Clone)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
    /// 0 = keep every sample.
    cap: usize,
    seen: u64,
    /// xorshift64 state for reservoir replacement (deterministic seed).
    rng: u64,
}

impl Default for Percentiles {
    fn default() -> Self {
        Percentiles::new()
    }
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true, cap: 0, seen: 0, rng: 0x9E37_79B9_7F4A_7C15 }
    }

    /// Keep at most `cap` samples via reservoir sampling.
    pub fn bounded(cap: usize) -> Self {
        let mut p = Percentiles::new();
        p.cap = cap.max(1);
        p
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x
    }

    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        if self.cap == 0 || self.xs.len() < self.cap {
            self.xs.push(x);
            self.sorted = false;
        } else {
            // Algorithm R: replace a random slot with prob cap/seen.
            let j = self.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.xs[j as usize] = x;
                self.sorted = false;
            }
        }
    }

    /// Samples currently held (≤ cap when bounded).
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Total samples ever offered.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0,1]; linear interpolation between order stats.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Coefficient of imbalance used by the elastic scheduler and load stats:
/// max(load) / mean(load). 1.0 == perfectly balanced.
pub fn imbalance(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    loads.iter().cloned().fold(f64::MIN, f64::max) / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in 1..=100 {
            p.add(x as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!(p.p99() > 98.0);
    }

    #[test]
    fn bounded_reservoir_caps_memory_and_stays_representative() {
        let mut p = Percentiles::bounded(128);
        for x in 0..100_000 {
            p.add(x as f64);
        }
        assert_eq!(p.len(), 128, "reservoir must not grow past its cap");
        assert_eq!(p.seen(), 100_000);
        // a uniform stream's sampled median should land near the middle
        let med = p.p50();
        assert!(
            med > 20_000.0 && med < 80_000.0,
            "reservoir median wildly off: {}",
            med
        );
        // unbounded default keeps everything
        let mut q = Percentiles::new();
        for x in 0..1000 {
            q.add(x as f64);
        }
        assert_eq!(q.len(), 1000);
    }

    #[test]
    fn imbalance_metric() {
        assert!((imbalance(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((imbalance(&[2.0, 1.0, 1.0]) - 1.5).abs() < 1e-12);
        assert_eq!(imbalance(&[]), 1.0);
    }
}
