//! Tiny leveled logger (stderr) with env-controlled verbosity.
//!
//! `SEMOE_LOG=debug|info|warn|error` (default `info`). Timestamps are
//! monotonic seconds since process start so logs from multi-threaded
//! workers interleave readably.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static START: Lazy<Instant> = Lazy::new(Instant::now);
static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn current_level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let from_env = match std::env::var("SEMOE_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    } as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the log level programmatically (tests, `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= current_level()
}

pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{:>9.3}s {} {}] {}", t, tag, target, msg);
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
    }
}
