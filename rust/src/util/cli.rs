//! Minimal CLI argument parser (subcommand + `--key value` + `--flag`).
//!
//! Drives `semoe <subcommand>` as well as every example and bench binary.
//! Deliberately boring: parse once into a map, typed getters with
//! defaults, and an auto-generated usage string.

use std::collections::BTreeMap;

/// Declared option (for usage text + validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]); `expect_subcommand` shifts the
    /// first bare word into `subcommand`.
    pub fn parse(raw: &[String], expect_subcommand: bool) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.values.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    a.values.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(name.to_string());
                }
            } else if expect_subcommand && a.subcommand.is_none() {
                a.subcommand = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    /// Parse from the process environment.
    pub fn from_env(expect_subcommand: bool) -> Result<Args, String> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&raw, expect_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Require a value or return a usage error.
    pub fn required(&self, name: &str) -> Result<String, String> {
        self.get(name)
            .map(|s| s.to_string())
            .ok_or_else(|| format!("missing required option --{}", name))
    }
}

/// Render a usage block from option specs.
pub fn usage(program: &str, about: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{}\n\n{}\n\nOptions:\n", program, about);
    for o in opts {
        let head = if o.is_flag {
            format!("  --{}", o.name)
        } else {
            format!("  --{} <v>", o.name)
        };
        let def = o.default.map(|d| format!(" [default: {}]", d)).unwrap_or_default();
        s.push_str(&format!("{:<28}{}{}\n", head, o.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(&v(&["train", "--preset", "base", "--steps=100", "--verbose"]), true).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str("preset", "tiny"), "base");
        assert_eq!(a.usize("steps", 1), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(&v(&["--x", "1.5"]), false).unwrap();
        assert_eq!(a.f64("x", 0.0), 1.5);
        assert_eq!(a.f64("y", 2.0), 2.0);
        assert!(a.required("missing").is_err());
        assert_eq!(a.required("x").unwrap(), "1.5");
    }

    #[test]
    fn positional_args() {
        let a = Args::parse(&v(&["run", "fileA", "--k", "v", "fileB"]), true).unwrap();
        assert_eq!(a.positional, v(&["fileA", "fileB"]));
    }

    #[test]
    fn flag_at_end_and_eq_form() {
        let a = Args::parse(&v(&["--a=b", "--last"]), false).unwrap();
        assert_eq!(a.get("a"), Some("b"));
        assert!(a.flag("last"));
    }

    #[test]
    fn usage_renders() {
        let u = usage("semoe", "MoE system", &[
            OptSpec { name: "preset", help: "model preset", default: Some("tiny"), is_flag: false },
            OptSpec { name: "verbose", help: "more logs", default: None, is_flag: true },
        ]);
        assert!(u.contains("--preset <v>"));
        assert!(u.contains("[default: tiny]"));
    }
}
