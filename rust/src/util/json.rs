//! Minimal JSON parser + writer.
//!
//! Used for: artifact manifests emitted by the AOT pipeline
//! (`artifacts/<preset>/manifest.json`), cluster/train config files, and
//! machine-readable bench reports. Supports the full JSON grammar except
//! `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — bench reports diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array; Null on OOB.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // --------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Insert into an object (no-op with a debug assert otherwise).
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        } else {
            debug_assert!(false, "Json::set on non-object");
        }
    }

    // -------------------------------------------------------------- writing

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ------------------------------------------------------------------ tests

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").at(2).get("b").as_str(), Some("x"));
        assert!(j.get("c").is_null());
        assert!(j.get("missing").is_null());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, round);
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} garbage").is_err());
    }

    #[test]
    fn manifest_shape_access() {
        // the exact access pattern the runtime registry uses
        let man = r#"{"artifacts":{"gating":{"file":"gating.hlo.txt",
            "inputs":[{"name":"logits","dtype":"f32","shape":[128,8]}]}}}"#;
        let j = Json::parse(man).unwrap();
        let inp = j.get("artifacts").get("gating").get("inputs").at(0);
        assert_eq!(inp.get("name").as_str(), Some("logits"));
        let shape: Vec<usize> = inp
            .get("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![128, 8]);
    }

    #[test]
    fn large_int_precision() {
        let j = Json::parse("104857600").unwrap();
        assert_eq!(j.as_i64(), Some(104857600));
        assert_eq!(j.to_string(), "104857600");
    }
}
