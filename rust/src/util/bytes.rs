//! Human-readable formatting for bytes, counts and durations (report
//! tables mirror the paper's units: GB memory, tokens/s, ms).

/// `1536 * 1024 * 1024` → `"1.50 GB"`.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} B", b)
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// `1_234_567` → `"1.23M"`.
pub fn human_count(n: u64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "B", "T"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{}", n)
    } else {
        format!("{:.2}{}", v, UNITS[u])
    }
}

/// Seconds → adaptive unit string.
pub fn human_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.2} h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KB");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024), "3.00 GB");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(31_085), "31.09K");
        assert_eq!(human_count(104_100_000_000), "104.10B");
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration(0.000_000_5), "500.0 ns");
        assert_eq!(human_duration(0.0123), "12.30 ms");
        assert_eq!(human_duration(5.0), "5.00 s");
        assert_eq!(human_duration(600.0), "10.0 min");
    }
}
