//! Cluster/hardware description: devices, nodes, clusters, and link
//! characteristics — the substrate for the network-topology model (§4.2)
//! and the cost-model simulator (Tables 1–2).
//!
//! Defaults are A100-pod numbers matching the paper's testbed; everything
//! is overridable from JSON so benches can sweep hardware what-ifs.

use crate::util::json::Json;

/// Physical link classes in the paper's fabric (Figure 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Intra-node GPU-GPU via NVLink/NVSwitch.
    NvLink,
    /// GPU <-> host memory via PCIe.
    Pcie,
    /// Host <-> NVMe SSD.
    Nvme,
    /// Node <-> ToR switch (NIC).
    Tor,
    /// ToR <-> leaf switch (same rail, cross-cluster).
    Leaf,
    /// Leaf <-> spine switch (cross-rail).
    Spine,
}

/// Per-link performance: bandwidth in bytes/s, latency in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkPerf {
    pub bandwidth: f64,
    pub latency: f64,
}

/// Whole-cluster description.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of clusters (pods) in the fabric.
    pub n_clusters: usize,
    /// Nodes per cluster.
    pub nodes_per_cluster: usize,
    /// GPUs per node (the paper's `p`; rails are indexed by GPU rank).
    pub gpus_per_node: usize,
    /// Device compute: dense bf16/fp16 FLOP/s (A100: 312e12).
    pub flops: f64,
    /// Achievable MFU for transformer workloads (calibrates the sim).
    pub mfu: f64,
    /// Device memory in bytes (A100-80G by default).
    pub gpu_mem: u64,
    /// Host memory per node in bytes.
    pub cpu_mem: u64,
    /// SSD capacity per node in bytes.
    pub ssd_cap: u64,
    pub nvlink: LinkPerf,
    pub pcie: LinkPerf,
    pub nvme: LinkPerf,
    pub tor: LinkPerf,
    pub leaf: LinkPerf,
    pub spine: LinkPerf,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_clusters: 1,
            nodes_per_cluster: 1,
            gpus_per_node: 8,
            flops: 312e12,
            mfu: 0.35,
            gpu_mem: 80 * (1 << 30),
            cpu_mem: 1024 * (1 << 30),
            ssd_cap: 8 * 1024 * (1 << 30),
            // Unidirectional effective bandwidths.
            nvlink: LinkPerf { bandwidth: 300e9, latency: 2e-6 },
            pcie: LinkPerf { bandwidth: 25e9, latency: 5e-6 },
            nvme: LinkPerf { bandwidth: 3.2e9, latency: 80e-6 },
            tor: LinkPerf { bandwidth: 25e9, latency: 5e-6 },   // 200Gb IB
            leaf: LinkPerf { bandwidth: 20e9, latency: 10e-6 },
            spine: LinkPerf { bandwidth: 16e9, latency: 20e-6 },
        }
    }
}

impl ClusterConfig {
    /// A single-node config with `g` GPUs.
    pub fn single_node(g: usize) -> Self {
        ClusterConfig { gpus_per_node: g, ..Default::default() }
    }

    /// `n` nodes of 8 GPUs in one cluster (the paper's multi-node rows).
    pub fn nodes(n: usize) -> Self {
        ClusterConfig { nodes_per_cluster: n, ..Default::default() }
    }

    pub fn total_gpus(&self) -> usize {
        self.n_clusters * self.nodes_per_cluster * self.gpus_per_node
    }

    pub fn total_nodes(&self) -> usize {
        self.n_clusters * self.nodes_per_cluster
    }

    pub fn perf(&self, kind: LinkKind) -> LinkPerf {
        match kind {
            LinkKind::NvLink => self.nvlink,
            LinkKind::Pcie => self.pcie,
            LinkKind::Nvme => self.nvme,
            LinkKind::Tor => self.tor,
            LinkKind::Leaf => self.leaf,
            LinkKind::Spine => self.spine,
        }
    }

    /// Effective device compute throughput (FLOP/s) after MFU derating.
    pub fn effective_flops(&self) -> f64 {
        self.flops * self.mfu
    }

    pub fn from_json(j: &Json) -> ClusterConfig {
        let d = ClusterConfig::default();
        let u = |k: &str, def: usize| j.get(k).as_usize().unwrap_or(def);
        let f = |k: &str, def: f64| j.get(k).as_f64().unwrap_or(def);
        let link = |k: &str, def: LinkPerf| {
            let o = j.get(k);
            if o.is_null() {
                def
            } else {
                LinkPerf {
                    bandwidth: o.get("bandwidth").as_f64().unwrap_or(def.bandwidth),
                    latency: o.get("latency").as_f64().unwrap_or(def.latency),
                }
            }
        };
        ClusterConfig {
            n_clusters: u("n_clusters", d.n_clusters),
            nodes_per_cluster: u("nodes_per_cluster", d.nodes_per_cluster),
            gpus_per_node: u("gpus_per_node", d.gpus_per_node),
            flops: f("flops", d.flops),
            mfu: f("mfu", d.mfu),
            gpu_mem: f("gpu_mem", d.gpu_mem as f64) as u64,
            cpu_mem: f("cpu_mem", d.cpu_mem as f64) as u64,
            ssd_cap: f("ssd_cap", d.ssd_cap as f64) as u64,
            nvlink: link("nvlink", d.nvlink),
            pcie: link("pcie", d.pcie),
            nvme: link("nvme", d.nvme),
            tor: link("tor", d.tor),
            leaf: link("leaf", d.leaf),
            spine: link("spine", d.spine),
        }
    }

    pub fn to_json(&self) -> Json {
        let link = |l: LinkPerf| {
            Json::obj(vec![
                ("bandwidth", Json::num(l.bandwidth)),
                ("latency", Json::num(l.latency)),
            ])
        };
        Json::obj(vec![
            ("n_clusters", Json::num(self.n_clusters as f64)),
            ("nodes_per_cluster", Json::num(self.nodes_per_cluster as f64)),
            ("gpus_per_node", Json::num(self.gpus_per_node as f64)),
            ("flops", Json::num(self.flops)),
            ("mfu", Json::num(self.mfu)),
            ("gpu_mem", Json::num(self.gpu_mem as f64)),
            ("cpu_mem", Json::num(self.cpu_mem as f64)),
            ("ssd_cap", Json::num(self.ssd_cap as f64)),
            ("nvlink", link(self.nvlink)),
            ("pcie", link(self.pcie)),
            ("nvme", link(self.nvme)),
            ("tor", link(self.tor)),
            ("leaf", link(self.leaf)),
            ("spine", link(self.spine)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let c = ClusterConfig { n_clusters: 2, nodes_per_cluster: 4, gpus_per_node: 8, ..Default::default() };
        assert_eq!(c.total_gpus(), 64);
        assert_eq!(c.total_nodes(), 8);
    }

    #[test]
    fn link_ordering_matches_fabric() {
        // The paper's premise: NVLink >> PCIe > leaf > spine.
        let c = ClusterConfig::default();
        assert!(c.nvlink.bandwidth > c.pcie.bandwidth);
        assert!(c.tor.bandwidth >= c.leaf.bandwidth);
        assert!(c.leaf.bandwidth > c.spine.bandwidth);
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterConfig::nodes(4);
        let back = ClusterConfig::from_json(&c.to_json());
        assert_eq!(c, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"gpus_per_node": 4}"#).unwrap();
        let c = ClusterConfig::from_json(&j);
        assert_eq!(c.gpus_per_node, 4);
        assert_eq!(c.flops, ClusterConfig::default().flops);
    }
}
