//! Preset catalogues.
//!
//! *Local* presets mirror `python/compile/configs.py` — these have AOT
//! artifacts and run for real on the CPU PJRT client.
//!
//! *Paper* presets are the exact configurations of the paper's evaluation
//! (Table 1 training rows, Table 2 inference rows, the Fig 10/11 models,
//! Table 3 UFO and Table 4 embedding sweeps). They exist for the
//! calibrated cost-model simulator; no artifacts are built for them.

use super::cluster::ClusterConfig;
use super::model::ModelConfig;

/// Local (artifact-backed) preset by name. Panics on unknown names —
/// these are compiled-in constants, not user input.
pub fn local_preset(name: &str) -> ModelConfig {
    let mk = |name: &str, v, h, nh, l, f, e, t, b| ModelConfig {
        name: name.to_string(),
        vocab_size: v,
        d_model: h,
        n_heads: nh,
        n_layers: l,
        d_ff: f,
        n_experts: e,
        seq_len: t,
        batch_size: b,
        capacity_factor: 2.0,
        aux_loss_weight: 1e-2,
    };
    match name {
        "tiny" => mk("tiny", 256, 64, 4, 2, 256, 4, 32, 4),
        "small" => mk("small", 1024, 128, 4, 2, 512, 8, 32, 4),
        "deep" => mk("deep", 1024, 128, 4, 12, 512, 8, 32, 4),
        "base" => mk("base", 4096, 256, 8, 4, 1024, 48, 64, 4),
        other => panic!("unknown local preset '{}'", other),
    }
}

/// One row of the paper's Table 1 (MoE-GPT training).
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Paper's reported total parameters, in billions.
    pub params_b: f64,
    pub n_experts: usize,
    pub gpus: usize,
    pub batch_size: usize,
    /// Paper-reported throughputs (tokens/s) for shape comparison.
    pub paper_deepspeed_tps: f64,
    pub paper_semoe_tps: f64,
    /// Paper-reported per-rank memory (GB).
    pub paper_deepspeed_mem_gb: f64,
    pub paper_semoe_mem_gb: f64,
}

/// The shared Table-1 backbone: heads=64, hidden=4096, vocab=50304, 12
/// layers, sequence length 1024 (GPT-2 style), fp16.
pub fn table1_model(n_experts: usize, batch_size: usize) -> ModelConfig {
    ModelConfig {
        name: format!("gpt-moe-{}e", n_experts),
        vocab_size: 50304,
        d_model: 4096,
        n_heads: 64,
        n_layers: 12,
        d_ff: 4 * 4096,
        n_experts,
        seq_len: 1024,
        batch_size,
        capacity_factor: 2.0,
        aux_loss_weight: 1e-2,
    }
}

pub fn table1_rows() -> Vec<Table1Row> {
    vec![
        Table1Row { params_b: 13.9, n_experts: 8, gpus: 8, batch_size: 8,
                    paper_deepspeed_tps: 24165.0, paper_semoe_tps: 31085.0,
                    paper_deepspeed_mem_gb: 68.9, paper_semoe_mem_gb: 56.8 },
        Table1Row { params_b: 26.8, n_experts: 16, gpus: 16, batch_size: 16,
                    paper_deepspeed_tps: 43691.0, paper_semoe_tps: 59136.0,
                    paper_deepspeed_mem_gb: 66.2, paper_semoe_mem_gb: 53.9 },
        Table1Row { params_b: 52.6, n_experts: 32, gpus: 32, batch_size: 32,
                    paper_deepspeed_tps: 82957.0, paper_semoe_tps: 113456.0,
                    paper_deepspeed_mem_gb: 66.8, paper_semoe_mem_gb: 54.5 },
        Table1Row { params_b: 104.1, n_experts: 64, gpus: 64, batch_size: 64,
                    paper_deepspeed_tps: 157728.0, paper_semoe_tps: 209970.0,
                    paper_deepspeed_mem_gb: 66.3, paper_semoe_mem_gb: 54.4 },
        Table1Row { params_b: 207.2, n_experts: 128, gpus: 128, batch_size: 128,
                    paper_deepspeed_tps: 283706.0, paper_semoe_tps: 376968.0,
                    paper_deepspeed_mem_gb: 66.4, paper_semoe_mem_gb: 54.3 },
    ]
}

/// One row of Table 2 (inference throughput).
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub params_b: f64,
    pub gpus: usize,
    pub batch_size: usize,
    pub paper_deepspeed_tps: f64,
    pub paper_semoe_tps: f64,
}

pub fn table2_rows() -> Vec<Table2Row> {
    vec![
        Table2Row { params_b: 10.0, gpus: 1, batch_size: 1,
                    paper_deepspeed_tps: 4303.0, paper_semoe_tps: 4551.0 },
        Table2Row { params_b: 106.5, gpus: 8, batch_size: 8,
                    paper_deepspeed_tps: 27215.0, paper_semoe_tps: 29681.0 },
        Table2Row { params_b: 209.6, gpus: 16, batch_size: 16,
                    paper_deepspeed_tps: 35310.0, paper_semoe_tps: 40059.0 },
    ]
}

/// Inference model matching a Table-2 parameter budget (experts chosen to
/// hit ~params_b at the Table-1 backbone dimensions).
pub fn table2_model(params_b: f64, batch_size: usize) -> ModelConfig {
    // Invert param_counts for the backbone dims: per-expert block is
    // e*(2*h*f + f + h) per layer.
    let mut m = table1_model(8, batch_size);
    let target = (params_b * 1e9) as usize;
    let per_expert_layer = 2 * m.d_model * m.d_ff + m.d_ff + m.d_model;
    // dense part with 0 experts:
    let mut probe = m.clone();
    probe.n_experts = 1;
    let dense = probe.dense_params();
    let e = ((target.saturating_sub(dense)) as f64
        / (m.n_layers * per_expert_layer) as f64)
        .round()
        .max(1.0) as usize;
    m.n_experts = e;
    m.name = format!("gpt-moe-infer-{:.1}b", params_b);
    m
}

/// Fig 10 ring-offload model: 32 experts, 58.2B params, 16×A100-40G.
pub fn fig10_model() -> ModelConfig {
    let mut m = table1_model(32, 16);
    m.name = "gpt-moe-58b-ring".into();
    // 58.2B with 32 experts needs ~13-14 layers at the backbone dims.
    m.n_layers = 13;
    m
}

/// Fig 11 hierarchical-AlltoAll model: 80.7B on 32 GPUs (4 nodes).
pub fn fig11_model() -> ModelConfig {
    let mut m = table1_model(32, 32);
    m.name = "gpt-moe-80b-a2a".into();
    m.n_layers = 18;
    m
}

/// Table 4 embedding-partition row (V100 testbed, vocab 50304).
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub hidden: usize,
    pub paper_baseline_mem_gb: f64,
    pub paper_partition_mem_gb: f64,
    pub paper_baseline_tps: f64,
    pub paper_partition_tps: f64,
}

pub fn table4_rows() -> Vec<Table4Row> {
    vec![
        Table4Row { hidden: 2048, paper_baseline_mem_gb: 7.46,
                    paper_partition_mem_gb: 5.78,
                    paper_baseline_tps: 144159.0, paper_partition_tps: 150161.0 },
        Table4Row { hidden: 4096, paper_baseline_mem_gb: 12.80,
                    paper_partition_mem_gb: 9.70,
                    paper_baseline_tps: 86237.0, paper_partition_tps: 95890.0 },
        Table4Row { hidden: 8192, paper_baseline_mem_gb: 27.80,
                    paper_partition_mem_gb: 20.49,
                    paper_baseline_tps: 40605.0, paper_partition_tps: 46938.0 },
    ]
}

/// Table 3: UFO multi-task loads (batch per task) and the paper's two
/// placements.
#[derive(Debug, Clone)]
pub struct Table3Setup {
    pub task_batches: Vec<usize>,
    pub imbalanced_gpus_per_task: Vec<usize>,
    pub balanced_gpus_per_task: Vec<usize>,
    pub paper_imbalanced_speed_per_card: f64,
    pub paper_balanced_speed_per_card: f64,
}

pub fn table3_setup() -> Table3Setup {
    Table3Setup {
        task_batches: vec![512, 256, 128, 128],
        imbalanced_gpus_per_task: vec![1, 1, 1, 1],
        balanced_gpus_per_task: vec![4, 2, 1, 1],
        paper_imbalanced_speed_per_card: 62.6,
        paper_balanced_speed_per_card: 74.0,
    }
}

/// The cluster each Table-1/2 row ran on (8 GPUs per node).
pub fn cluster_for_gpus(gpus: usize) -> ClusterConfig {
    if gpus <= 8 {
        ClusterConfig::single_node(gpus)
    } else {
        ClusterConfig::nodes((gpus + 7) / 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_param_counts_track_paper() {
        // Paper's own count column: 13.9B @ 8 experts ... 207.2B @ 128.
        for row in table1_rows() {
            let m = table1_model(row.n_experts, row.batch_size);
            let total_b = m.param_counts().total as f64 / 1e9;
            let rel = (total_b - row.params_b).abs() / row.params_b;
            assert!(rel < 0.12, "experts={} got {:.1}B want {:.1}B",
                    row.n_experts, total_b, row.params_b);
        }
    }

    #[test]
    fn table2_models_hit_target_params() {
        for row in table2_rows() {
            let m = table2_model(row.params_b, row.batch_size);
            let total_b = m.param_counts().total as f64 / 1e9;
            let rel = (total_b - row.params_b).abs() / row.params_b;
            assert!(rel < 0.15, "{:.1}B got {:.1}B", row.params_b, total_b);
        }
    }

    #[test]
    fn fig_models_param_budgets() {
        let f10 = fig10_model().param_counts().total as f64 / 1e9;
        assert!((f10 - 58.2).abs() / 58.2 < 0.15, "fig10 {:.1}B", f10);
        let f11 = fig11_model().param_counts().total as f64 / 1e9;
        assert!((f11 - 80.7).abs() / 80.7 < 0.15, "fig11 {:.1}B", f11);
    }

    #[test]
    fn clusters() {
        assert_eq!(cluster_for_gpus(8).total_gpus(), 8);
        assert_eq!(cluster_for_gpus(128).total_gpus(), 128);
        assert_eq!(cluster_for_gpus(128).total_nodes(), 16);
    }
}
