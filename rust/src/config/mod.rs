//! Typed configuration: model presets (mirroring `python/compile/configs.py`),
//! cluster/hardware descriptions, and training options. All configs load
//! from / dump to JSON via [`crate::util::json`].

pub mod model;
pub mod cluster;
pub mod train;
pub mod presets;

pub use cluster::{ClusterConfig, LinkKind};
pub use model::ModelConfig;
pub use train::{RouteSourceChoice, TrainConfig};
