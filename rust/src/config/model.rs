//! Model configuration — the rust mirror of `python/compile/configs.py`.
//!
//! The authoritative copy of a preset's dimensions travels in the AOT
//! manifest (`artifacts/<preset>/manifest.json`); [`ModelConfig::from_json`]
//! loads it so rust and python can never drift. The param-count formulas
//! are re-implemented here (and cross-checked in tests against the
//! manifest's layout) because the simulator needs them for paper-scale
//! models that have no artifacts.

use crate::util::json::Json;

/// Switch-Transformer style decoder-only MoE LM dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub capacity_factor: f64,
    pub aux_loss_weight: f64,
}

/// Parameter counts by group (units: parameters, not bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParamCounts {
    pub embed: usize,
    pub per_layer: usize,
    pub per_layer_dense: usize,
    pub per_layer_sparse: usize,
    pub head: usize,
    pub total: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch_size * self.seq_len
    }

    /// GShard capacity: ceil(cf * tokens / experts).
    pub fn expert_capacity(&self) -> usize {
        let t = (self.capacity_factor * self.tokens_per_batch() as f64) as usize;
        ((t + self.n_experts - 1) / self.n_experts).max(1)
    }

    /// Mirrors `MoEConfig.param_counts` in python.
    pub fn param_counts(&self) -> ParamCounts {
        let (h, f, e, v) = (self.d_model, self.d_ff, self.n_experts, self.vocab_size);
        let attn = 4 * h * h + 4 * h;
        let ln = 4 * h;
        let router = h * e + e;
        let experts = e * (h * f + f + f * h + h);
        let per_layer = attn + ln + router + experts;
        let embed = v * h;
        let head = h * v + 2 * h;
        ParamCounts {
            embed,
            per_layer,
            per_layer_dense: attn + ln + router,
            per_layer_sparse: experts,
            head,
            total: embed + self.n_layers * per_layer + head,
        }
    }

    /// Total dense (always-activated) parameters: embed + head + per-layer
    /// dense. The paper's `D` in the §2.1 storage formulas.
    pub fn dense_params(&self) -> usize {
        let c = self.param_counts();
        c.embed + c.head + self.n_layers * c.per_layer_dense
    }

    /// Total sparse (expert) parameters. The paper's `S`.
    pub fn sparse_params(&self) -> usize {
        self.n_layers * self.param_counts().per_layer_sparse
    }

    /// Parse from a manifest's `"preset"` object.
    pub fn from_json(j: &Json) -> Result<ModelConfig, String> {
        let req = |k: &str| -> Result<usize, String> {
            j.get(k).as_usize().ok_or_else(|| format!("preset missing '{}'", k))
        };
        Ok(ModelConfig {
            name: j.get("name").as_str().unwrap_or("unnamed").to_string(),
            vocab_size: req("vocab_size")?,
            d_model: req("d_model")?,
            n_heads: req("n_heads")?,
            n_layers: req("n_layers")?,
            d_ff: req("d_ff")?,
            n_experts: req("n_experts")?,
            seq_len: req("seq_len")?,
            batch_size: req("batch_size")?,
            capacity_factor: j.get("capacity_factor").as_f64().unwrap_or(2.0),
            aux_loss_weight: j.get("aux_loss_weight").as_f64().unwrap_or(1e-2),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("n_experts", Json::num(self.n_experts as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("capacity_factor", Json::num(self.capacity_factor)),
            ("aux_loss_weight", Json::num(self.aux_loss_weight)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::local_preset;

    #[test]
    fn capacity_matches_python_formula() {
        let cfg = local_preset("tiny");
        // tiny: cf=2.0, tokens=128, E=4 -> ceil(256/4) = 64
        assert_eq!(cfg.tokens_per_batch(), 128);
        assert_eq!(cfg.expert_capacity(), 64);
    }

    #[test]
    fn counts_sum() {
        let cfg = local_preset("base");
        let c = cfg.param_counts();
        assert_eq!(
            c.total,
            c.embed + cfg.n_layers * c.per_layer + c.head
        );
        assert_eq!(cfg.dense_params() + cfg.sparse_params(), c.total);
        assert!(c.total > 90_000_000, "base should be ~100M, got {}", c.total);
        // the paper's premise: sparse dominates
        assert!(cfg.sparse_params() as f64 / c.total as f64 > 0.9);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = local_preset("small");
        let j = cfg.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }
}
