//! Training-run options consumed by `train::Trainer` and the examples.

use crate::dist::DispatchMode;
use crate::util::json::Json;

/// How parameters are held during training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamResidency {
    /// All parameter state on-device (fits-in-memory fast path).
    Resident,
    /// Hierarchical offload: dense on device, sparse on SSD with a CPU
    /// cache + 2D prefetch (the paper's §2.1–2.2 mode).
    Offload,
}

/// Which `moe::RouteSource` plans the offload trainer's expert axis —
/// the A/B knob for repeated-corpus workloads. Every step the planner's
/// hit rate against the kernel-emitted exact sets is counted in
/// `PrefetchStats::{plan_hit_experts,plan_missed_experts}`, so the two
/// choices are directly comparable on a live run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteSourceChoice {
    /// Predict each step from the batch's own token embeddings (router
    /// over ln2-normalized embeddings, attention skipped). The right
    /// default: every training step is a fresh batch.
    EmbeddingProxy,
    /// Carry the previous step's kernel-emitted exact sets (falling
    /// back to the proxy until a full sweep has been observed). Wins
    /// when consecutive batches repeat routing — epoch-scale repeated
    /// corpora, curriculum replays.
    CarriedKernel,
}

impl RouteSourceChoice {
    /// Strict parse — `None` for anything but the two accepted names.
    /// CLI surfaces bail on `None` (a typo must not silently fall back
    /// to the proxy and invalidate the A/B); `from_json` stays lenient
    /// like the rest of the config family.
    pub fn parse(s: &str) -> Option<RouteSourceChoice> {
        match s {
            "proxy" => Some(RouteSourceChoice::EmbeddingProxy),
            "carried" => Some(RouteSourceChoice::CarriedKernel),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RouteSourceChoice::EmbeddingProxy => "proxy",
            RouteSourceChoice::CarriedKernel => "carried",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub preset: String,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub residency: ParamResidency,
    /// Number of data-parallel workers (in-process device mesh size).
    pub dp_degree: usize,
    /// Prefetch lookahead in layers (0 disables overlap).
    pub prefetch_depth: usize,
    /// Expert-granular (2D) prefetch: stream only the experts the batch
    /// routes to, plus the hot set. When false the sparse lane degrades
    /// to 1D layer-granular staging (every expert, every layer).
    pub expert_prefetch: bool,
    /// Fraction of per-layer routed load whose experts get pinned in the
    /// CPU cache (`LoadStats::hot_experts` coverage).
    pub hot_frac: f64,
    /// Which planner predicts the expert axis (see [`RouteSourceChoice`]).
    pub route_source: RouteSourceChoice,
    /// Pipelined (split) sweeps: run each layer's `layer_dense` prefix
    /// while that layer's planned SSD fetches drain, then one
    /// `expert_tail` over the prefix-emitted exact routing — plan
    /// misses become pre-tail demand fetches instead of tail re-runs.
    /// When false the fused `layer_fwd` plan/repair sweep runs.
    pub pipelined: bool,
    /// CPU cache capacity as a fraction of total sparse bytes.
    pub cpu_cache_frac: f64,
    /// Zipf skew of the synthetic corpus (0 = uniform tokens).
    pub corpus_skew: f64,
    /// Expert-parallel world size (`train --workers N`): N ranks on
    /// threads, each owning 1/N of every layer's experts and running
    /// their AdamW, exchanging updated blocks end-of-step — bit-identical
    /// to the single-host path (docs/distributed.md §Training). 1 =
    /// single host. Mutually exclusive with `dp_degree > 1`.
    pub dist_world: usize,
    /// Which lane moves the pipelined sweep's MoE work when
    /// `dist_world > 1`: `weights` (the replicated store; no mesh
    /// traffic on the forward), `tokens` (ship routed activations to
    /// expert owners), or `auto` (byte-cost vote — degenerates to
    /// `weights` in training, where the weight lane is mesh-free).
    pub dist_dispatch: DispatchMode,
    /// Log every N steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "tiny".into(),
            steps: 20,
            lr: 1e-3,
            seed: 0,
            residency: ParamResidency::Resident,
            dp_degree: 1,
            prefetch_depth: 1,
            expert_prefetch: true,
            hot_frac: 0.5,
            route_source: RouteSourceChoice::EmbeddingProxy,
            pipelined: false,
            cpu_cache_frac: 0.5,
            corpus_skew: 1.05,
            dist_world: 1,
            dist_dispatch: DispatchMode::Weights,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn from_json(j: &Json) -> TrainConfig {
        let d = TrainConfig::default();
        TrainConfig {
            preset: j.get("preset").as_str().unwrap_or(&d.preset).to_string(),
            steps: j.get("steps").as_usize().unwrap_or(d.steps),
            lr: j.get("lr").as_f64().unwrap_or(d.lr),
            seed: j.get("seed").as_i64().unwrap_or(d.seed as i64) as u64,
            residency: match j.get("residency").as_str() {
                Some("offload") => ParamResidency::Offload,
                _ => ParamResidency::Resident,
            },
            dp_degree: j.get("dp_degree").as_usize().unwrap_or(d.dp_degree),
            prefetch_depth: j.get("prefetch_depth").as_usize().unwrap_or(d.prefetch_depth),
            expert_prefetch: j.get("expert_prefetch").as_bool().unwrap_or(d.expert_prefetch),
            hot_frac: j.get("hot_frac").as_f64().unwrap_or(d.hot_frac),
            route_source: j
                .get("route_source")
                .as_str()
                .and_then(RouteSourceChoice::parse)
                .unwrap_or(d.route_source),
            pipelined: j.get("pipelined").as_bool().unwrap_or(d.pipelined),
            cpu_cache_frac: j.get("cpu_cache_frac").as_f64().unwrap_or(d.cpu_cache_frac),
            corpus_skew: j.get("corpus_skew").as_f64().unwrap_or(d.corpus_skew),
            dist_world: j.get("dist_world").as_usize().unwrap_or(d.dist_world),
            dist_dispatch: j
                .get("dist_dispatch")
                .as_str()
                .and_then(DispatchMode::parse)
                .unwrap_or(d.dist_dispatch),
            log_every: j.get("log_every").as_usize().unwrap_or(d.log_every),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("lr", Json::num(self.lr)),
            ("seed", Json::num(self.seed as f64)),
            (
                "residency",
                Json::str(match self.residency {
                    ParamResidency::Resident => "resident",
                    ParamResidency::Offload => "offload",
                }),
            ),
            ("dp_degree", Json::num(self.dp_degree as f64)),
            ("prefetch_depth", Json::num(self.prefetch_depth as f64)),
            ("expert_prefetch", Json::Bool(self.expert_prefetch)),
            ("hot_frac", Json::num(self.hot_frac)),
            ("route_source", Json::str(self.route_source.as_str())),
            ("pipelined", Json::Bool(self.pipelined)),
            ("cpu_cache_frac", Json::num(self.cpu_cache_frac)),
            ("corpus_skew", Json::num(self.corpus_skew)),
            ("dist_world", Json::num(self.dist_world as f64)),
            ("dist_dispatch", Json::str(self.dist_dispatch.as_str())),
            ("log_every", Json::num(self.log_every as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = TrainConfig::default();
        c.residency = ParamResidency::Offload;
        c.route_source = RouteSourceChoice::CarriedKernel;
        c.pipelined = true;
        c.steps = 300;
        c.dist_world = 4;
        c.dist_dispatch = DispatchMode::Tokens;
        let back = TrainConfig::from_json(&c.to_json());
        assert_eq!(c, back);
    }

    #[test]
    fn defaults_on_empty() {
        let c = TrainConfig::from_json(&Json::parse("{}").unwrap());
        assert_eq!(c, TrainConfig::default());
    }
}
