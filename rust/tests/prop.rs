//! Property-based tests over coordinator invariants: routing, fusion,
//! collectives, cache and JSON. A deterministic in-tree harness (the
//! vendored crate set has no proptest): each property runs across many
//! seeded random cases; failures print the seed for replay.

use semoe::comm::hierarchical::{flat_a2a, hierarchical_a2a};
use semoe::comm::{FusionBuffer, GradientBuckets, Mesh};
use semoe::infer::{AdmissionConfig, AdmissionQueue, AdmitError, Request};
use semoe::moe::{top1_route, DispatchPlan, ExpertPlacement};
use semoe::storage::{CacheConfig, CachePolicy, CpuCache};
use semoe::util::json::Json;
use semoe::util::Rng;

const CASES: u64 = 64;

fn for_cases(name: &str, f: impl Fn(&mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xFACE ^ (seed * 7919));
        // Panic messages carry the seed.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{}' failed at seed {}: {:?}", name, seed, e);
        }
    }
}

// ---------------------------------------------------------------- routing

#[test]
fn prop_routing_conservation() {
    for_cases("routing_conservation", |rng| {
        let t = rng.range(1, 128);
        let e = rng.range(2, 32);
        let cap = rng.range(1, t + 1);
        let logits: Vec<f32> = (0..t * e).map(|_| rng.normal() as f32 * 2.0).collect();
        let r = top1_route(&logits, t, e, cap);
        // every token either kept with a valid slot or dropped
        let mut per_expert = vec![0usize; e];
        for i in 0..t {
            assert!(r.expert[i] < e);
            if r.keep[i] {
                assert!(r.pos[i] < cap);
                per_expert[r.expert[i]] += 1;
                assert!(r.gate[i] > 0.0 && r.gate[i] <= 1.0);
            } else {
                assert_eq!(r.gate[i], 0.0);
            }
        }
        assert!(per_expert.iter().all(|&c| c <= cap));
        // probability-mass summaries
        assert!((r.me.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!((r.ce.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        // aux loss is bounded below by the balanced value... up to fp
        assert!(r.aux_loss() >= 0.99);
    });
}

#[test]
fn prop_dispatch_plan_conserves_tokens() {
    for_cases("dispatch_plan", |rng| {
        let e = rng.range(2, 24);
        let devs = rng.range(1, e + 1);
        let t = rng.range(1, 96);
        let logits: Vec<f32> = (0..t * e).map(|_| rng.normal() as f32).collect();
        let r = top1_route(&logits, t, e, t);
        let kept = r.keep.iter().filter(|&&k| k).count();
        let placement = if rng.next_f64() < 0.5 {
            ExpertPlacement::contiguous(e, devs)
        } else {
            ExpertPlacement::round_robin(e, devs)
        };
        let plan = DispatchPlan::build(&[r], &placement, rng.range(4, 64));
        assert_eq!(plan.tokens.iter().flatten().sum::<usize>(), kept);
        assert_eq!(plan.recv_loads().iter().sum::<usize>(), kept);
    });
}

// ----------------------------------------------------------------- fusion

#[test]
fn prop_fusion_pack_unpack_identity() {
    for_cases("fusion_identity", |rng| {
        let n = rng.range(1, 24);
        let mut fb = FusionBuffer::new();
        let mut data = Vec::new();
        for i in 0..n {
            let len = rng.range(1, 64);
            fb.register(&format!("t{}", i), len);
            data.push((0..len).map(|_| rng.normal() as f32).collect::<Vec<f32>>());
        }
        for (i, d) in data.iter().enumerate() {
            fb.pack(&format!("t{}", i), d);
        }
        // chunk boundaries tile the buffer exactly
        let chunk = rng.range(1, fb.len().max(2));
        let chunks = fb.chunked(chunk);
        assert_eq!(chunks.iter().map(|(_, l)| l).sum::<usize>(), fb.len());
        for w in chunks.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0);
        }
        for (i, d) in data.iter().enumerate() {
            assert_eq!(fb.unpack(&format!("t{}", i)), &d[..]);
        }
    });
}

#[test]
fn prop_buckets_fire_exactly_once_per_pass() {
    for_cases("buckets_once", |rng| {
        let n = rng.range(1, 16);
        let cap = rng.range(1, 256);
        let mut gb = GradientBuckets::new(cap);
        let lens: Vec<usize> = (0..n).map(|_| rng.range(1, 32)).collect();
        for (i, &l) in lens.iter().enumerate() {
            gb.register(&format!("g{}", i), l);
        }
        gb.start_pass();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut fired = 0usize;
        let mut total = 0usize;
        for &i in &order {
            if let Some(r) = gb.deposit(&format!("g{}", i), &vec![1.0; lens[i]]) {
                fired += 1;
                total += r.data.len();
            }
        }
        assert_eq!(fired, gb.n_buckets());
        assert_eq!(total, lens.iter().sum::<usize>());
    });
}

// -------------------------------------------------------------- admission

/// Randomized admit/cancel/poll/time-advance sequences against a shadow
/// model of the queue. Invariants: FIFO dispatch order, no request
/// dispatched twice, cancelled requests never dispatch, the queue bound
/// is respected (typed rejection beyond it), live engines always drain
/// waiting work, and the enqueue/dispatch/cancel counters conserve.
#[test]
fn prop_admission_queue_invariants() {
    use std::collections::HashSet;
    use std::time::{Duration, Instant};

    let smoke = std::env::var("SEMOE_SMOKE").map(|v| v == "1").unwrap_or(false);
    let ops = if smoke { 80 } else { 250 };
    for_cases("admission_queue", |rng| {
        let max_queue = rng.range(1, 12);
        let linger = Duration::from_millis(rng.below(8) as u64);
        let mut q = AdmissionQueue::new(AdmissionConfig { max_queue, linger });
        let mut now = Instant::now();
        let mut next_id = 1u64;
        // shadow model
        let mut queued: Vec<u64> = Vec::new();
        let mut dispatched: Vec<u64> = Vec::new();
        let mut cancelled: HashSet<u64> = HashSet::new();
        for _ in 0..ops {
            match rng.below(5) {
                0 | 1 => {
                    // push, sometimes with a stale arrival stamp (requeue)
                    let id = next_id;
                    next_id += 1;
                    let arrived = now - Duration::from_millis(rng.below(20) as u64);
                    let res = q.push(Request { id, prompt: vec![1], max_tokens: 1, arrived });
                    if queued.len() >= max_queue {
                        assert_eq!(res, Err(AdmitError::QueueFull), "bound must reject");
                    } else {
                        assert!(res.is_ok());
                        queued.push(id);
                    }
                }
                2 => {
                    // cancel a random id from the whole history
                    if next_id > 1 {
                        let id = rng.range(1, next_id as usize) as u64;
                        let was_queued = queued.contains(&id);
                        assert_eq!(q.cancel(id), was_queued, "cancel must hit iff queued");
                        if was_queued {
                            queued.retain(|&x| x != id);
                            cancelled.insert(id);
                        }
                    }
                }
                3 => {
                    // poll for admission
                    let free = rng.below(5);
                    let live = rng.below(3);
                    let got = q.pop_ready(free, live, now);
                    assert!(got.len() <= free, "never over-admit");
                    if live > 0 && free > 0 && !queued.is_empty() {
                        assert!(!got.is_empty(), "live engine must drain waiting work");
                    }
                    for r in &got {
                        assert_eq!(r.id, queued.remove(0), "FIFO order violated");
                        assert!(!cancelled.contains(&r.id), "cancelled request dispatched");
                        assert!(!dispatched.contains(&r.id), "double dispatch");
                        dispatched.push(r.id);
                    }
                }
                _ => now += Duration::from_millis(rng.below(6) as u64),
            }
            assert_eq!(q.len(), queued.len(), "queue length drifted from the model");
            assert!(q.len() <= max_queue, "queue bound breached");
        }
        // conservation: everything enqueued is dispatched, cancelled, or
        // still waiting — nothing leaks, nothing is double-counted.
        let s = q.stats();
        assert_eq!(s.enqueued as usize, dispatched.len() + cancelled.len() + q.len());
        assert_eq!(s.admitted as usize, dispatched.len());
        assert_eq!(s.cancelled as usize, cancelled.len());
        // and a final flush drains exactly the shadow queue, in order
        let drained: Vec<u64> = q.drain().iter().map(|r| r.id).collect();
        assert_eq!(drained, queued);
    });
}

// ------------------------------------------------------------ collectives

#[test]
fn prop_hierarchical_a2a_equals_flat() {
    // randomized shapes/payloads over a small mesh
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let p = rng.range(1, 4);
        let nodes = rng.range(1, 4);
        let world = p * nodes;
        let handles = Mesh::new(world);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|mut h| {
                std::thread::spawn(move || {
                    let mut r = Rng::new(1000 + h.rank() as u64);
                    let chunks: Vec<Vec<f32>> = (0..h.world())
                        .map(|d| (0..r.range(0, 6)).map(|k| (h.rank() * 100 + d * 10 + k) as f32).collect())
                        .collect();
                    let flat = flat_a2a(&mut h, chunks.clone());
                    let (hier, _) = hierarchical_a2a(&mut h, p, chunks);
                    assert_eq!(flat, hier);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    }
}

// ------------------------------------------------------- token dispatch

/// Randomized token-dispatch collectives over a small mesh: every rank
/// ships a random set of kept activation rows to their expert owners,
/// owners apply a deterministic per-expert transform, and the replies
/// must land at home bit-exact and in request order. The measured
/// `payload_bytes` must equal `CostModel::token_dispatch_layer_bytes`
/// exactly — the planner's vote is only sound if the accounting it is
/// based on is.
#[test]
fn prop_token_dispatch_payload_matches_cost_model() {
    use semoe::comm::A2aStrategy;
    use semoe::config::presets::{cluster_for_gpus, local_preset};
    use semoe::dist::dispatch_layer_tokens;
    use semoe::sim::CostModel;

    let preset = local_preset("deep");
    let d_model = preset.d_model;
    let cm = CostModel::new(preset, cluster_for_gpus(8));
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xD15 ^ (seed * 7919));
        let p = rng.range(1, 4);
        let nodes = rng.range(1, 4);
        let world = (p * nodes).max(2);
        let n_experts = world + rng.range(0, 6);
        let strategy =
            if rng.next_f64() < 0.5 { A2aStrategy::Flat } else { A2aStrategy::Hierarchical };
        let handles = Mesh::new(world);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|mut h| {
                std::thread::spawn(move || {
                    let me = h.rank();
                    let mut r = Rng::new(5000 + seed * 100 + me as u64);
                    let kept: Vec<(usize, Vec<f32>)> = (0..r.range(0, 12))
                        .map(|_| {
                            let e = r.below(n_experts);
                            let row: Vec<f32> =
                                (0..d_model).map(|_| r.normal() as f32).collect();
                            (e, row)
                        })
                        .collect();
                    let owner_of = |e: usize| e % world;
                    let mut run_tail = |reqs: &[(usize, Vec<f32>)]| {
                        for &(e, _) in reqs {
                            assert_eq!(owner_of(e), me, "request routed to a non-owner");
                        }
                        Ok(reqs
                            .iter()
                            .map(|(e, row)| {
                                row.iter().map(|v| v * (*e as f32 + 1.0)).collect()
                            })
                            .collect())
                    };
                    let out = dispatch_layer_tokens(
                        &mut h, strategy, p, &owner_of, &kept, d_model, &mut run_tail,
                    )
                    .unwrap();
                    // replies in request order, transform applied bit-exact
                    assert_eq!(out.rows.len(), kept.len());
                    for ((e, row), got) in kept.iter().zip(&out.rows) {
                        let want: Vec<f32> =
                            row.iter().map(|v| v * (*e as f32 + 1.0)).collect();
                        assert_eq!(got, &want, "reply diverged for expert {}", e);
                    }
                    (kept.len(), out.payload_bytes)
                })
            })
            .collect();
        for j in joins {
            let (kept_rows, payload) = j.join().unwrap();
            assert_eq!(payload, (2 * kept_rows * d_model * 4) as u64);
            assert_eq!(
                payload as f64,
                cm.token_dispatch_layer_bytes(kept_rows as f64),
                "measured payload diverged from the cost-model prediction"
            );
        }
    }
}

/// Randomized worlds × skews × dispatch modes on the real decode path:
/// weight dispatch, token dispatch and the auto planner must all produce
/// outputs bitwise equal to each other and to a single host — the lane
/// moves different bytes, never different math.
#[test]
fn prop_dispatch_modes_bitwise_equal_across_random_worlds() {
    use semoe::dist::{run_infer_group, zipf_prompts, DispatchMode, DistConfig};
    use semoe::runtime::ModelArtifacts;

    let preset = "tiny";
    let arts = ModelArtifacts::load(preset).expect("tiny artifacts (run `make artifacts`)");
    let (vocab, b) = (arts.preset.vocab_size, arts.preset.batch_size);
    let smoke = std::env::var("SEMOE_SMOKE").map(|v| v == "1").unwrap_or(false);
    let cases = if smoke { 2 } else { 5 };
    for seed in 0..cases {
        let mut rng = Rng::new(0xA2A ^ (seed * 7919));
        let w = rng.range(2, 5);
        let s = rng.next_f64() * 1.5;
        let n_new = rng.range(1, 3);
        let prompts: Vec<Vec<Vec<i32>>> = (0..w)
            .map(|r| zipf_prompts(vocab, b, 4, s, 9000 + seed * 100 + r as u64))
            .collect();
        let solo = run_infer_group(
            preset,
            &DistConfig::default(),
            std::slice::from_ref(&prompts[0]),
            n_new,
            7,
        )
        .expect("single-host run");
        let want_rank0 = solo.ranks[0].outputs.clone();
        let mut all_ranks_ref: Option<Vec<Vec<Vec<i32>>>> = None;
        for mode in [DispatchMode::Weights, DispatchMode::Tokens, DispatchMode::Auto] {
            let cfg = DistConfig { workers: w, dispatch: mode, ..DistConfig::default() };
            let g = run_infer_group(preset, &cfg, &prompts, n_new, 7).expect("group run");
            assert_eq!(
                g.ranks[0].outputs,
                want_rank0,
                "rank 0 diverged from single host (seed {} w {} mode {})",
                seed,
                w,
                mode.as_str()
            );
            let outs: Vec<Vec<Vec<i32>>> =
                g.ranks.iter().map(|r| r.outputs.clone()).collect();
            match &all_ranks_ref {
                None => all_ranks_ref = Some(outs),
                Some(want) => assert_eq!(
                    &outs,
                    want,
                    "outputs diverged across dispatch modes (seed {} w {} mode {})",
                    seed,
                    w,
                    mode.as_str()
                ),
            }
            if mode == DispatchMode::Tokens {
                let moved: u64 = g.ranks.iter().map(|r| r.dist.token_bytes).sum();
                let row_bytes = (2 * arts.preset.d_model * 4) as u64;
                assert!(moved > 0, "token mode must ship activation rows");
                assert_eq!(
                    moved % row_bytes,
                    0,
                    "token payload must be a whole number of round-trip rows"
                );
                assert!(g.ranks.iter().all(|r| r.dist.weight_layers == 0));
            }
        }
    }
}

// ---------------------------------------------------------------- storage

#[test]
fn prop_cache_never_exceeds_capacity_and_loses_no_dirty_data() {
    for_cases("cache_capacity", |rng| {
        let cap_blocks = rng.range(1, 8);
        let block_len = rng.range(1, 32);
        let cap_bytes = cap_blocks * block_len * 4;
        let mut cache = CpuCache::new(CacheConfig {
            capacity_bytes: cap_bytes,
            policy: CachePolicy::Alg1,
            hit_threshold: 2.0,
            beta: 0.5,
            decay_every: 4,
        });
        // shadow model: last written value per key + where it lives
        let n_keys = rng.range(2, 20);
        let mut truth: Vec<Option<f32>> = vec![None; n_keys]; // dirty value if cached-dirty
        let mut ssd: Vec<f32> = (0..n_keys).map(|k| k as f32).collect();
        for _ in 0..200 {
            let k = rng.below(n_keys);
            let key = format!("k{}", k);
            match rng.below(3) {
                0 => {
                    // read-through
                    if cache.get(&key).is_none() {
                        for ev in cache.insert(&key, vec![ssd[k]; block_len], false) {
                            let ek: usize = ev.key[1..].parse().unwrap();
                            if ev.dirty {
                                ssd[ek] = ev.data[0];
                                truth[ek] = None;
                            }
                        }
                    }
                }
                1 => {
                    // update (write-back)
                    let val = rng.normal() as f32;
                    if cache.update(&key, vec![val; block_len]) {
                        truth[k] = Some(val);
                    }
                }
                _ => cache.end_step(),
            }
            assert!(cache.bytes() <= cap_bytes.max(block_len * 4));
        }
        // drain and verify every dirty value lands on "SSD"
        for ev in cache.drain() {
            let ek: usize = ev.key[1..].parse().unwrap();
            if ev.dirty {
                ssd[ek] = ev.data[0];
                truth[ek] = None;
            }
        }
        for (k, t) in truth.iter().enumerate() {
            assert!(t.is_none(), "dirty value for key {} lost", k);
        }
    });
}

// ---------------------------------------------------- sparse expert layout

/// Randomized layouts for the expert-axis splicing surface shared by the
/// offload trainer, the checkpoint lane and serving hot-swap
/// ([`SparseLayout::gather`]/[`scatter`]). Invariants: scatter∘gather is
/// the identity on the fused tail (bit-exact), every expert's ranges
/// partition the tail with no overlap (a swapped expert can never alias
/// a neighbour's bytes), and mutating one expert's block leaves every
/// other expert's gather bit-unchanged.
#[test]
fn prop_sparse_layout_gather_scatter_roundtrip() {
    use semoe::runtime::ParamSpec;
    use semoe::storage::SparseLayout;

    for_cases("sparse_layout_roundtrip", |rng| {
        let n_experts = rng.range(1, 9);
        let n_members = rng.range(1, 5);
        let mut specs = Vec::new();
        for i in 0..n_members {
            let per = rng.range(1, 17);
            specs.push(ParamSpec {
                name: format!("layer0.m{}", i),
                shape: vec![n_experts, per],
                sparse: true,
                numel: n_experts * per,
            });
            // Noise the builder must ignore: dense members and layer-1
            // copies of the same tensors.
            specs.push(ParamSpec {
                name: format!("layer0.dense{}", i),
                shape: vec![per],
                sparse: false,
                numel: per,
            });
            specs.push(ParamSpec {
                name: format!("layer1.m{}", i),
                shape: vec![n_experts, per],
                sparse: true,
                numel: n_experts * per,
            });
        }
        let layout = SparseLayout::from_specs(&specs, n_experts).unwrap();
        assert_eq!(layout.n_experts(), n_experts);
        assert_eq!(layout.tail_len(), layout.expert_len() * n_experts);

        // The experts' ranges partition the tail: every element owned
        // exactly once — gather/scatter can never alias a neighbour.
        let mut owner = vec![usize::MAX; layout.tail_len()];
        for e in 0..n_experts {
            let mut total = 0usize;
            for (off, len) in layout.expert_ranges(e) {
                total += len;
                for slot in owner.iter_mut().skip(off).take(len) {
                    assert_eq!(*slot, usize::MAX, "expert {} aliases expert {}", e, *slot);
                    *slot = e;
                }
            }
            assert_eq!(total, layout.expert_len());
        }
        assert!(owner.iter().all(|&o| o != usize::MAX), "tail fully covered");

        // scatter ∘ gather is the identity, bit for bit.
        let tail: Vec<f32> = (0..layout.tail_len()).map(|_| rng.normal() as f32).collect();
        let mut roundtrip = tail.clone();
        for e in 0..n_experts {
            let block = layout.gather(e, &tail);
            assert_eq!(block.len(), layout.expert_len());
            layout.scatter(e, &block, &mut roundtrip);
        }
        assert_eq!(roundtrip, tail, "scatter(gather) must be the identity");

        // Mutating one expert touches exactly its own bytes.
        let victim = rng.below(n_experts);
        let before: Vec<Vec<f32>> = (0..n_experts).map(|e| layout.gather(e, &tail)).collect();
        let swapped: Vec<f32> =
            (0..layout.expert_len()).map(|_| rng.normal() as f32).collect();
        let mut tail2 = tail.clone();
        layout.scatter(victim, &swapped, &mut tail2);
        for e in 0..n_experts {
            let got = layout.gather(e, &tail2);
            if e == victim {
                assert_eq!(got, swapped, "swapped expert must read back its new bytes");
            } else {
                assert_eq!(got, before[e], "expert {} bytes moved by a neighbour swap", e);
            }
        }
    });
}

// ------------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.normal() * 1e3).round()),
            3 => {
                let s: String = (0..rng.below(12))
                    .map(|_| char::from_u32(rng.range(32, 0x24F) as u32).unwrap_or('x'))
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{}", i), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for_cases("json_roundtrip", |rng| {
        let v = gen(rng, 0);
        let compact = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, compact);
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, pretty);
    });
}
