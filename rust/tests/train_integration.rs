//! Integration: trainers, checkpointing and data-parallel offload
//! training over real artifacts (tiny preset). Requires `make artifacts`.

use std::rc::Rc;

use semoe::comm::Mesh;
use semoe::config::train::TrainConfig;
use semoe::dist::run_train_group;
use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::train::{checkpoint, OffloadTrainer, ResidentTrainer, SyntheticCorpus};

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig { preset: "tiny".into(), steps, lr: 1e-3, ..Default::default() }
}

#[test]
fn checkpoint_roundtrip() {
    let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
    let mut tr = ResidentTrainer::new(arts.clone(), cfg(2)).unwrap();
    tr.step().unwrap();
    tr.step().unwrap();
    let dir = std::env::temp_dir().join(format!("semoe_ckpt_{}", std::process::id()));
    checkpoint::save(&dir, &arts, tr.params()).unwrap();
    let loaded = checkpoint::load(&dir, &arts).unwrap();
    assert_eq!(loaded.len(), tr.params().len());
    for (a, b) in loaded.iter().zip(tr.params()) {
        assert_eq!(a, b);
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn offload_prefetch_depths_agree() {
    // The lookahead window must not change the math, only the overlap.
    let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
    let m = arts.preset.clone();
    let mut corpus = SyntheticCorpus::new(m.vocab_size, 1.05, 7);
    let batches: Vec<(HostTensor, HostTensor)> = (0..2)
        .map(|_| {
            let (t, l) = corpus.next_batch(m.batch_size, m.seq_len);
            (
                HostTensor::from_i32(&[m.batch_size, m.seq_len], t),
                HostTensor::from_i32(&[m.batch_size, m.seq_len], l),
            )
        })
        .collect();
    let mut losses: Vec<Vec<f32>> = Vec::new();
    for depth in [0usize, 2] {
        let mut c = cfg(2);
        c.prefetch_depth = depth;
        let mut tr = OffloadTrainer::new(arts.clone(), c, None).unwrap();
        let mut ls = Vec::new();
        for (t, l) in &batches {
            ls.push(tr.step_on(t.clone(), l.clone()).unwrap().loss);
        }
        losses.push(ls);
    }
    assert_eq!(losses[0], losses[1], "lookahead must be numerics-neutral");
}

#[test]
fn data_parallel_offload_training_converges_and_syncs() {
    // 2 DP ranks, different data, bucketed grad averaging: ranks must
    // hold identical parameters after every step, and loss must drop.
    let world = 2;
    let handles = Mesh::new(world);
    let joins: Vec<_> = handles
        .into_iter()
        .map(|mesh| {
            std::thread::spawn(move || {
                let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
                let mut tr = OffloadTrainer::new(arts, cfg(4), Some(mesh)).unwrap();
                let mut first = f32::NAN;
                let mut last = f32::NAN;
                for s in 0..4 {
                    let m = tr.step().unwrap();
                    if s == 0 {
                        first = m.loss;
                    }
                    last = m.loss;
                }
                // fingerprint of the (synced) head params
                let fp: f32 = {
                    let store = tr.into_store().unwrap();
                    let _ = store; // sparse state differs only by layer order; use loss trajectory
                    0.0
                };
                (first, last, fp)
            })
        })
        .collect();
    let results: Vec<(f32, f32, f32)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for (first, last, _) in &results {
        assert!(last < first, "loss should drop: {} -> {}", first, last);
    }
    // ranks see different data but identical parameter updates → their
    // loss sequences differ, but not wildly (same model state).
    let (f0, l0, _) = results[0];
    let (f1, l1, _) = results[1];
    assert!((f0 - f1).abs() < 1.0, "init losses comparable: {} vs {}", f0, f1);
    assert!((l0 - l1).abs() < 1.0);
}

#[test]
fn dist_expert_parallel_training_is_bit_identical_to_single_host() {
    // The tentpole acceptance check for `train --workers N`: every rank
    // of an expert-parallel group must produce the exact loss bits of a
    // single-host offload trainer with the same config — the exchange
    // moves optimizer state as bytes, never through a floating-point
    // reduction (docs/distributed.md §Training).
    for pipelined in [false, true] {
        let mut c = cfg(3);
        c.pipelined = pipelined;
        let solo: Vec<u32> = {
            let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
            let mut tr = OffloadTrainer::new(arts, c.clone(), None).unwrap();
            (0..c.steps).map(|_| tr.step().unwrap().loss.to_bits()).collect()
        };
        c.dist_world = 2;
        let ranks = run_train_group(&c).unwrap();
        assert_eq!(ranks.len(), 2);
        let mut exchanged = 0u64;
        for r in &ranks {
            let got: Vec<u32> = r.metrics.iter().map(|m| m.loss.to_bits()).collect();
            assert_eq!(
                got, solo,
                "rank {} diverged from single host (pipelined={})",
                r.rank, pipelined
            );
            exchanged += r.dist.a2a_bytes;
            assert!(r.dist.remote_fetches > 0, "rank {} received no peer blocks", r.rank);
            assert!(r.comm.ops > 0, "rank {} fired no collectives", r.rank);
        }
        assert!(exchanged > 0, "the exchange must move real bytes");
    }
}

#[test]
fn cpu_adamw_matches_artifact() {
    use semoe::train::optimizer::cpu_adamw;
    let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
    let exe = arts.load_exe("adamw_embed").unwrap();
    let n = arts.preset.param_counts().embed;
    let mut rng = semoe::util::Rng::new(11);
    let p = HostTensor::randn(&[n], 1.0, &mut rng);
    let g = HostTensor::randn(&[n], 1.0, &mut rng);
    let m = HostTensor::randn(&[n], 0.1, &mut rng);
    let v = {
        let mut t = HostTensor::randn(&[n], 0.1, &mut rng);
        for x in t.as_f32_mut().unwrap() {
            *x = x.abs();
        }
        t
    };
    for step in [1.0f32, 7.0] {
        let out = exe
            .run(&[
                p.clone(), g.clone(), m.clone(), v.clone(),
                HostTensor::scalar_f32(step),
                HostTensor::scalar_f32(3e-3),
            ])
            .unwrap();
        let mut pc = p.as_f32().unwrap().to_vec();
        let mut mc = m.as_f32().unwrap().to_vec();
        let mut vc = v.as_f32().unwrap().to_vec();
        cpu_adamw(&mut pc, g.as_f32().unwrap(), &mut mc, &mut vc, step, 3e-3);
        let want = out[0].as_f32().unwrap();
        for i in (0..n).step_by(311) {
            assert!(
                (pc[i] - want[i]).abs() < 1e-5 * want[i].abs().max(1.0),
                "step {} i {}: {} vs {}",
                step, i, pc[i], want[i]
            );
        }
        let wm = out[1].as_f32().unwrap();
        for i in (0..n).step_by(311) {
            assert!((mc[i] - wm[i]).abs() < 1e-6);
        }
    }
}

#[test]
fn resident_trainer_is_deterministic() {
    let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
    let run = || {
        let mut tr = ResidentTrainer::new(arts.clone(), cfg(3)).unwrap();
        (0..3).map(|_| tr.step().unwrap().loss).collect::<Vec<f32>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn offload_store_survives_flush_cycle() {
    let arts = Rc::new(ModelArtifacts::load("tiny").unwrap());
    let mut tr = OffloadTrainer::new(arts.clone(), cfg(2), None).unwrap();
    let a = tr.step().unwrap();
    tr.flush().unwrap();
    let b = tr.step().unwrap();
    assert!(b.loss.is_finite());
    assert!(b.loss < a.loss + 1.0);
    let store = tr.into_store().unwrap();
    assert!(store.cache_stats().hits + store.cache_stats().misses > 0);
}
