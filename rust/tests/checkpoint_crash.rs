//! Crash-injection harness for the incremental, expert-granular
//! checkpoint lane (docs/training.md §Checkpointing).
//!
//! Part A drives the write protocol directly — artifact-free — with a
//! simulated training loop: random expert subsets get dirtied, and
//! every checkpoint attempt may die at a randomized [`Fault`] point
//! (mid-blob, between writebacks, mid-publish). After every crash the
//! previously committed checkpoint must read back bit-equal and fully
//! checksum-verify, torn leftovers must never be loadable, and a retry
//! must commit.
//!
//! Part B (artifact-gated, tiny preset) proves the trainer contract:
//! resume from a checkpoint — including one taken right before a
//! crashed write — continues bit-equal to a run that never stopped.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::rc::Rc;

use semoe::config::train::TrainConfig;
use semoe::runtime::ModelArtifacts;
use semoe::train::checkpoint::{self, DenseEntry, Fault, SparseEntry};
use semoe::train::OffloadTrainer;
use semoe::util::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("semoe_crash_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// ------------------------------------------------ Part A: protocol fuzzing

const LAYERS: usize = 2;
const EXPERTS: usize = 4;
const BLOCK: usize = 6; // f32 per p/m/v segment

/// The simulated trainer's authoritative state for one record.
#[derive(Clone, PartialEq, Debug)]
struct Record {
    stamp: u64,
    p: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
}

/// After every attempt — crash or commit — the directory must hold
/// exactly the last *committed* snapshot, bit for bit, and verify clean.
fn assert_committed(dir: &Path, committed: &Option<(usize, HashMap<String, Record>)>) {
    match committed {
        None => assert!(
            !dir.join(checkpoint::MANIFEST_FILE).exists(),
            "no checkpoint ever committed, yet a manifest exists"
        ),
        Some((cstep, map)) => {
            let man = checkpoint::read_manifest(dir).unwrap();
            assert_eq!(man.step, *cstep, "committed step drifted");
            assert_eq!(man.entries.len(), map.len(), "committed entry set drifted");
            let s = checkpoint::verify(dir).unwrap();
            assert_eq!(s.step, *cstep);
            for (key, rec) in map {
                let e = man.entry(key).unwrap_or_else(|| panic!("entry '{}' lost", key));
                assert_eq!(e.stamp, rec.stamp, "stamp drifted for '{}'", key);
                let (p, m, v) = checkpoint::load_entry(dir, e).unwrap();
                assert_eq!(p, rec.p, "p drifted for '{}'", key);
                assert_eq!(m, rec.m, "m drifted for '{}'", key);
                assert_eq!(v, rec.v, "v drifted for '{}'", key);
            }
        }
    }
}

fn run_fuzz_case(seed: u64, steps: usize) {
    let dir = tmp_dir(&format!("fuzz{}", seed));
    let mut rng = Rng::new(0xC0FFEE ^ (seed * 6151));

    // Live simulated state: every expert starts dirty (first checkpoint
    // persists a full baseline) plus one always-rewritten dense record.
    let mut truth: Vec<Vec<Record>> = (0..LAYERS)
        .map(|l| {
            (0..EXPERTS)
                .map(|e| Record {
                    stamp: 0,
                    p: vec![(l * EXPERTS + e) as f32; BLOCK],
                    m: vec![0.0; BLOCK],
                    v: vec![0.0; BLOCK],
                })
                .collect()
        })
        .collect();
    let mut dense = Record { stamp: 0, p: vec![0.5; BLOCK], m: vec![0.0; BLOCK], v: vec![0.0; BLOCK] };
    let mut dirty: HashSet<(usize, usize)> =
        (0..LAYERS).flat_map(|l| (0..EXPERTS).map(move |e| (l, e))).collect();
    let mut committed: Option<(usize, HashMap<String, Record>)> = None;

    for step in 1..=steps {
        // "Train": route a random expert subset, mutate its state.
        let routed = rng.range(1, LAYERS * EXPERTS + 1);
        for _ in 0..routed {
            let (l, e) = (rng.below(LAYERS), rng.below(EXPERTS));
            let r = &mut truth[l][e];
            for i in 0..BLOCK {
                r.p[i] += rng.normal() as f32 * 0.1;
                r.m[i] = r.m[i] * 0.9 + rng.normal() as f32 * 0.01;
                r.v[i] = (r.v[i] * 0.99).abs() + 1e-6;
            }
            r.stamp = step as u64;
            dirty.insert((l, e));
        }
        for x in dense.p.iter_mut() {
            *x += rng.normal() as f32 * 0.05;
        }
        dense.stamp = step as u64;

        // Not every step checkpoints; the last one always does, cleanly.
        let last = step == steps;
        if !last && rng.below(2) == 0 {
            continue;
        }
        let mut keys: Vec<(usize, usize)> = dirty.iter().copied().collect();
        keys.sort();
        let sparse: Vec<SparseEntry> = keys
            .iter()
            .map(|&(l, e)| {
                let r = &truth[l][e];
                SparseEntry {
                    layer: l,
                    expert: e,
                    stamp: r.stamp,
                    p: r.p.clone(),
                    m: r.m.clone(),
                    v: r.v.clone(),
                }
            })
            .collect();
        let dense_entries = vec![DenseEntry {
            key: "dense.embed".into(),
            p: dense.p.clone(),
            m: dense.m.clone(),
            v: dense.v.clone(),
        }];
        let pending = sparse.len() + dense_entries.len();
        let fault = if last {
            None
        } else {
            match rng.below(5) {
                0 => Some(Fault::TornBlob { index: rng.below(pending) }),
                1 => Some(Fault::AfterEntries { count: rng.below(pending + 1) }),
                2 => Some(Fault::ManifestRename),
                _ => None,
            }
        };
        match checkpoint::write_incremental(&dir, "sim", step, &sparse, &dense_entries, fault) {
            Ok(rep) => {
                assert_eq!(rep.entries_written, pending);
                // Commit: snapshot the full truth (carried entries were
                // clean, so previous committed values equal truth too).
                let mut map = HashMap::new();
                for l in 0..LAYERS {
                    for e in 0..EXPERTS {
                        map.insert(checkpoint::sparse_key(l, e), truth[l][e].clone());
                    }
                }
                map.insert(
                    "dense.embed".into(),
                    Record { stamp: step as u64, ..dense.clone() },
                );
                committed = Some((step, map));
                dirty.clear();
            }
            Err(e) => {
                // Only the injected crash may fail a write here.
                assert!(
                    format!("{}", e).contains("fault injected"),
                    "unexpected write failure at seed {} step {}: {:#}",
                    seed,
                    step,
                    e
                );
            }
        }
        assert_committed(&dir, &committed);
    }

    // The final clean checkpoint committed the full truth; every blob on
    // disk that looks step-versioned must be referenced (GC left no
    // torn/superseded leftovers behind).
    let (cstep, map) = committed.as_ref().expect("final clean checkpoint must commit");
    assert_eq!(*cstep, steps);
    assert_eq!(map.len(), LAYERS * EXPERTS + 1);
    let man = checkpoint::read_manifest(&dir).unwrap();
    let referenced: HashSet<String> = man.entries.iter().map(|e| e.blob.clone()).collect();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().into_string().unwrap();
        if let Some(stem) = name.strip_suffix(".bin") {
            let versioned = stem
                .rsplit_once(".s")
                .map_or(false, |(_, n)| !n.is_empty() && n.bytes().all(|b| b.is_ascii_digit()));
            if versioned {
                assert!(referenced.contains(stem), "stale blob '{}' survived GC", name);
            }
        }
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn randomized_fault_points_never_lose_a_committed_checkpoint() {
    let smoke = std::env::var("SEMOE_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (seeds, steps) = if smoke { (6u64, 8) } else { (24u64, 16) };
    for seed in 0..seeds {
        // Panic messages carry the seed (prop.rs harness idiom).
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_fuzz_case(seed, steps)));
        if let Err(e) = result {
            panic!("crash-injection fuzz failed at seed {}: {:?}", seed, e);
        }
    }
}

#[test]
fn torn_committed_blob_is_rejected_with_remedy() {
    let dir = tmp_dir("torn_commit");
    let sparse = [SparseEntry {
        layer: 1,
        expert: 2,
        stamp: 4,
        p: vec![1.0; BLOCK],
        m: vec![0.1; BLOCK],
        v: vec![0.01; BLOCK],
    }];
    checkpoint::write_incremental(&dir, "sim", 4, &sparse, &[], None).unwrap();
    let man = checkpoint::read_manifest(&dir).unwrap();
    let e = man.entry("layer1.expert2").unwrap();
    // Truncate the committed blob to an aligned half — the torn-write
    // shape a power loss leaves behind.
    let path = dir.join(format!("{}.bin", e.blob));
    let raw = std::fs::read(&path).unwrap();
    std::fs::write(&path, &raw[..raw.len() / 2 / 4 * 4]).unwrap();

    let msg = format!("{:#}", checkpoint::load_entry(&dir, e).unwrap_err());
    assert!(msg.contains("layer1.expert2"), "names the entry: {}", msg);
    assert!(msg.contains("torn write"), "states the fault: {}", msg);
    assert!(msg.contains("resume from an older checkpoint"), "gives a remedy: {}", msg);
    assert!(checkpoint::verify(&dir).is_err(), "verify must refuse the torn checkpoint");
    let _ = std::fs::remove_dir_all(dir);
}

// --------------------------------------- Part B: trainer resume (tiny arts)

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig { preset: "tiny".into(), steps, lr: 1e-3, ..Default::default() }
}

fn arts_or_skip() -> Option<Rc<ModelArtifacts>> {
    match ModelArtifacts::load("tiny") {
        Ok(a) => Some(Rc::new(a)),
        Err(_) => None, // artifacts not built; Part A covers the protocol
    }
}

/// Order-independent bit-identity fingerprint of a committed checkpoint.
fn manifest_fingerprint(dir: &Path) -> Vec<(String, String, u64)> {
    let man = checkpoint::read_manifest(dir).unwrap();
    let mut fp: Vec<(String, String, u64)> =
        man.entries.iter().map(|e| (e.key.clone(), e.sha256.clone(), e.stamp)).collect();
    fp.sort();
    fp
}

#[test]
fn resume_from_mid_run_checkpoint_is_bit_equal_to_uninterrupted() {
    let arts = match arts_or_skip() {
        Some(a) => a,
        None => return,
    };
    let dir_mid = tmp_dir("resume_mid");
    let dir_a = tmp_dir("resume_final_a");
    let dir_b = tmp_dir("resume_final_b");

    // Uninterrupted reference, dropping a checkpoint after step 3.
    let mut a = OffloadTrainer::new(arts.clone(), cfg(6), None).unwrap();
    let mut losses_a = Vec::new();
    for s in 0..6 {
        if s == 3 {
            let rep = a.checkpoint_to(&dir_mid).unwrap();
            assert!(rep.entries_written > 0, "baseline checkpoint must move bytes");
        }
        losses_a.push(a.step().unwrap().loss);
    }
    a.flush().unwrap();
    a.checkpoint_to(&dir_a).unwrap();

    // Restart from the step-3 checkpoint and run the remaining steps.
    let mut b = OffloadTrainer::resume_from(arts.clone(), cfg(6), None, &dir_mid).unwrap();
    let mut losses_b = Vec::new();
    for _ in 3..6 {
        losses_b.push(b.step().unwrap().loss);
    }
    b.flush().unwrap();
    b.checkpoint_to(&dir_b).unwrap();

    assert_eq!(&losses_a[3..], &losses_b[..], "resumed losses must be bit-equal");
    assert_eq!(
        manifest_fingerprint(&dir_a),
        manifest_fingerprint(&dir_b),
        "final parameter + optimizer state must be bit-equal"
    );
    for d in [dir_mid, dir_a, dir_b] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn crash_mid_checkpoint_then_resume_matches_uninterrupted() {
    let arts = match arts_or_skip() {
        Some(a) => a,
        None => return,
    };
    let dir = tmp_dir("crash_resume");
    let dir_ref = tmp_dir("crash_ref");

    // The run that dies: commit at step 2, train on, crash mid-blob
    // while checkpointing step 4.
    let mut tr = OffloadTrainer::new(arts.clone(), cfg(5), None).unwrap();
    tr.step().unwrap();
    tr.step().unwrap();
    tr.checkpoint_to(&dir).unwrap();
    tr.step().unwrap();
    tr.step().unwrap();
    let err = tr.checkpoint_to_with_fault(&dir, Some(Fault::TornBlob { index: 0 })).unwrap_err();
    assert!(format!("{}", err).contains("fault injected"));
    drop(tr); // the crash

    // The survivor is the step-2 checkpoint, fully intact.
    let s = checkpoint::verify(&dir).unwrap();
    assert_eq!(s.step, 2, "committed checkpoint must survive the crash");

    // Resume it and run to completion.
    let mut r = OffloadTrainer::resume_from(arts.clone(), cfg(5), None, &dir).unwrap();
    let mut resumed = Vec::new();
    for _ in 2..5 {
        resumed.push(r.step().unwrap().loss);
    }
    r.flush().unwrap();
    r.checkpoint_to(&dir).unwrap();

    // Uninterrupted reference.
    let mut u = OffloadTrainer::new(arts.clone(), cfg(5), None).unwrap();
    let mut reference = Vec::new();
    for _ in 0..5 {
        reference.push(u.step().unwrap().loss);
    }
    u.flush().unwrap();
    u.checkpoint_to(&dir_ref).unwrap();

    assert_eq!(&reference[2..], &resumed[..], "post-crash losses must be bit-equal");
    assert_eq!(
        manifest_fingerprint(&dir),
        manifest_fingerprint(&dir_ref),
        "post-crash final state must be bit-equal"
    );
    for d in [dir, dir_ref] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn idle_checkpoint_moves_only_dense_bytes() {
    let arts = match arts_or_skip() {
        Some(a) => a,
        None => return,
    };
    let dir = tmp_dir("idle_bytes");
    let mut tr = OffloadTrainer::new(arts, cfg(2), None).unwrap();
    tr.step().unwrap();
    let baseline = tr.checkpoint_to(&dir).unwrap();
    // Nothing dirtied since: only the always-rewritten dense records
    // move; every expert is carried forward by manifest reference.
    let idle = tr.checkpoint_to(&dir).unwrap();
    assert!(idle.entries_written < baseline.entries_written);
    assert_eq!(idle.entries_carried, baseline.entries_written - idle.entries_written);
    assert!(idle.bytes_written < baseline.bytes_written);
    let _ = std::fs::remove_dir_all(dir);
}
