//! Integration: load real AOT artifacts (tiny preset) and verify numerics
//! against rust-side oracles. Requires `make artifacts`.

use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::util::Rng;

fn arts() -> ModelArtifacts {
    ModelArtifacts::load("tiny").expect("tiny artifacts (run `make artifacts`)")
}

#[test]
fn manifest_matches_config_formulas() {
    let a = arts();
    let total: usize = a.params().iter().map(|p| p.numel).sum();
    assert_eq!(total, a.preset.param_counts().total);
    let sparse: usize = a.params().iter().filter(|p| p.sparse).map(|p| p.numel).sum();
    assert_eq!(sparse, a.preset.sparse_params());
}

#[test]
fn gating_uniform_logits_balances() {
    let a = arts();
    let exe = a.load_exe("gating").unwrap();
    let t = a.preset.tokens_per_batch();
    let e = a.preset.n_experts;
    let logits = HostTensor::zeros(&[t, e]);
    let out = exe.run(&[logits]).unwrap();
    // outputs: expert, gate, pos, keep, me, ce
    assert_eq!(out.len(), 6);
    let me = out[4].as_f32().unwrap();
    for &m in me {
        assert!((m - 1.0 / e as f32).abs() < 1e-6, "me {}", m);
    }
    let ce = out[5].as_f32().unwrap();
    assert!((ce.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    // all tokens pick the same argmax expert under ties -> ce is one-hot
    assert!(ce.iter().cloned().fold(0.0f32, f32::max) > 0.99);
}

#[test]
fn gating_capacity_is_enforced() {
    let a = arts();
    let exe = a.load_exe("gating").unwrap();
    let t = a.preset.tokens_per_batch();
    let e = a.preset.n_experts;
    let cap = a.preset.expert_capacity();
    // Strongly bias all tokens to expert 0 -> drops beyond capacity.
    let mut data = vec![0.0f32; t * e];
    for i in 0..t {
        data[i * e] = 10.0;
    }
    let out = exe.run(&[HostTensor::from_f32(&[t, e], data)]).unwrap();
    let keep = out[3].as_f32().unwrap();
    let kept: f32 = keep.iter().sum();
    assert_eq!(kept as usize, cap.min(t));
}

#[test]
fn adamw_matches_rust_oracle() {
    let a = arts();
    let exe = a.load_exe("adamw_embed").unwrap();
    let n = a.preset.param_counts().embed;
    let mut rng = Rng::new(7);
    let p = HostTensor::randn(&[n], 1.0, &mut rng);
    let g = HostTensor::randn(&[n], 1.0, &mut rng);
    let m = HostTensor::zeros(&[n]);
    let v = HostTensor::zeros(&[n]);
    let step = HostTensor::scalar_f32(1.0);
    let lr = HostTensor::scalar_f32(0.01);
    let out = exe
        .run(&[p.clone(), g.clone(), m.clone(), v.clone(), step, lr])
        .unwrap();
    let (b1, b2, eps, wd) = (0.9f32, 0.95f32, 1e-8f32, 0.01f32);
    let pv = p.as_f32().unwrap();
    let gv = g.as_f32().unwrap();
    let got = out[0].as_f32().unwrap();
    for i in (0..n).step_by(997) {
        let m1 = (1.0 - b1) * gv[i];
        let v1 = (1.0 - b2) * gv[i] * gv[i];
        let mhat = m1 / (1.0 - b1);
        let vhat = v1 / (1.0 - b2);
        let want = pv[i] - 0.01 * (mhat / (vhat.sqrt() + eps) + wd * pv[i]);
        assert!(
            (got[i] - want).abs() < 1e-5 * want.abs().max(1.0),
            "i={} got={} want={}",
            i,
            got[i],
            want
        );
    }
}

#[test]
fn embed_fwd_is_row_lookup() {
    let a = arts();
    let exe = a.load_exe("embed_fwd").unwrap();
    let (b, t) = (a.preset.batch_size, a.preset.seq_len);
    let (vcb, h) = (a.preset.vocab_size, a.preset.d_model);
    // embed[i][j] = i + j/1000
    let mut em = vec![0.0f32; vcb * h];
    for i in 0..vcb {
        for j in 0..h {
            em[i * h + j] = i as f32 + j as f32 / 1000.0;
        }
    }
    let mut rng = Rng::new(3);
    let toks: Vec<i32> = (0..b * t).map(|_| rng.below(vcb) as i32).collect();
    let out = exe
        .run(&[
            HostTensor::from_i32(&[b, t], toks.clone()),
            HostTensor::from_f32(&[vcb, h], em),
        ])
        .unwrap();
    let x = out[0].as_f32().unwrap();
    for k in 0..b * t {
        assert_eq!(x[k * h], toks[k] as f32);
        assert!((x[k * h + 5] - (toks[k] as f32 + 0.005)).abs() < 1e-6);
    }
}

#[test]
fn layer_fwd_shapes_and_determinism() {
    let a = arts();
    assert_eq!(a.contract_version(), semoe::runtime::CONTRACT_VERSION);
    let exe = a.load_exe("layer_fwd").unwrap();
    let mut rng = Rng::new(11);
    let inputs: Vec<HostTensor> = exe
        .spec
        .inputs
        .iter()
        .map(|s| HostTensor::randn(&s.shape, 0.05, &mut rng))
        .collect();
    let out1 = exe.run(&inputs).unwrap();
    let out2 = exe.run(&inputs).unwrap();
    // Contract v3: y, aux, the routing quadruple, and the dense-prefix
    // activations h/moe_in — addressed by name.
    assert_eq!(out1.len(), 8);
    let iy = exe.output_index("y").unwrap();
    let ie = exe.output_index("route_expert").unwrap();
    let ig = exe.output_index("route_gate").unwrap();
    let ih = exe.output_index("h").unwrap();
    let (b, t) = (a.preset.batch_size, a.preset.seq_len);
    assert_eq!(out1[iy].shape, vec![b, t, a.preset.d_model]);
    assert_eq!(out1[ih].shape, vec![b, t, a.preset.d_model]);
    assert_eq!(out1[iy], out2[iy], "execution must be deterministic");
    let aux = out1[exe.output_index("aux").unwrap()].scalar().unwrap();
    assert!(aux.is_finite() && aux > 0.0);
    // Routing outputs: every token names a real expert, deterministically.
    assert_eq!(out1[ie].shape, vec![b, t]);
    assert_eq!(out1[ie], out2[ie], "routing must be deterministic");
    let ids = out1[ie].as_i32().unwrap();
    assert!(ids.iter().all(|&e| e >= 0 && (e as usize) < a.preset.n_experts));
    let gates = out1[ig].as_f32().unwrap();
    assert!(gates.iter().all(|&g| (0.0..=1.0).contains(&g)));
}

/// The contract-v3 composition, on the REAL artifacts: running
/// `expert_tail` on the fused `layer_fwd`'s emitted activations with the
/// same expert weights must reproduce `y` bit for bit — this is the
/// soundness basis of tail-only plan-miss repair in both engines.
#[test]
fn expert_tail_composes_bitwise_with_layer_fwd() {
    let a = arts();
    let fused = a.load_exe("layer_fwd").unwrap();
    let tail = a.load_exe("expert_tail").unwrap();
    let mut rng = Rng::new(17);
    let inputs: Vec<HostTensor> = fused
        .spec
        .inputs
        .iter()
        .map(|s| {
            if s.dtype == semoe::runtime::DType::I32 {
                HostTensor::from_i32(&s.shape, vec![0; s.shape.iter().product::<usize>().max(1)])
            } else {
                HostTensor::randn(&s.shape, 0.05, &mut rng)
            }
        })
        .collect();
    let out = fused.run(&inputs).unwrap();
    // Tail inputs by name: the activations/routing from the fused run,
    // then the expert tensors from the fused input list.
    let mut tail_in: Vec<HostTensor> = Vec::new();
    for name in ["h", "moe_in", "route_expert", "route_gate", "route_pos", "route_keep"] {
        tail_in.push(out[fused.output_index(name).unwrap()].clone());
    }
    for name in ["w1", "b1", "w2", "b2"] {
        let pos = fused
            .spec
            .inputs
            .iter()
            .position(|i| i.name == name)
            .expect("expert weight in layer_fwd signature");
        tail_in.push(inputs[pos].clone());
    }
    let y_tail = tail.run(&tail_in).unwrap().remove(tail.output_index("y").unwrap());
    let iy = fused.output_index("y").unwrap();
    assert_eq!(y_tail, out[iy], "expert_tail ∘ layer_fwd activations must equal fused y");
}

/// `layer_dense` carries no expert weights in its signature, and its
/// outputs agree bitwise with the fused entry's dense-prefix outputs.
#[test]
fn layer_dense_signature_and_parity() {
    let a = arts();
    let fused = a.load_exe("layer_fwd").unwrap();
    let dense = a.load_exe("layer_dense").unwrap();
    for banned in ["w1", "b1", "w2", "b2"] {
        assert!(
            !dense.spec.inputs.iter().any(|i| i.name == banned),
            "layer_dense must not take expert weights ({})",
            banned
        );
    }
    let mut rng = Rng::new(23);
    let inputs: Vec<HostTensor> = fused
        .spec
        .inputs
        .iter()
        .map(|s| HostTensor::randn(&s.shape, 0.05, &mut rng))
        .collect();
    let fused_out = fused.run(&inputs).unwrap();
    // layer_dense's inputs are a prefix-by-name of layer_fwd's.
    let dense_in: Vec<HostTensor> = dense
        .spec
        .inputs
        .iter()
        .map(|s| {
            let pos = fused.spec.inputs.iter().position(|i| i.name == s.name).unwrap();
            inputs[pos].clone()
        })
        .collect();
    let dense_out = dense.run(&dense_in).unwrap();
    for name in ["h", "moe_in", "aux", "route_expert", "route_gate", "route_pos", "route_keep"] {
        assert_eq!(
            dense_out[dense.output_index(name).unwrap()],
            fused_out[fused.output_index(name).unwrap()],
            "layer_dense '{}' must match the fused dense prefix",
            name
        );
    }
}

#[test]
fn layer_fwd_missing_output_is_actionable() {
    let a = arts();
    let exe = a.load_exe("layer_fwd").unwrap();
    let err = exe.output_index("no_such_output").unwrap_err();
    let msg = format!("{}", err);
    assert!(msg.contains("rebuild the artifacts"), "actionable: {}", msg);
}

#[test]
fn expert_ffn_zero_input_gives_bias_path() {
    let a = arts();
    let exe = a.load_exe("expert_ffn").unwrap();
    let spec = exe.spec.inputs.clone();
    // zero x and zero biases -> zero output
    let inputs: Vec<HostTensor> = spec.iter().map(|s| HostTensor::zeros(&s.shape)).collect();
    let out = exe.run(&inputs).unwrap();
    let y = out[0].as_f32().unwrap();
    assert!(y.iter().all(|&v| v == 0.0));
}

#[test]
fn device_buffer_path_matches_host_path() {
    let a = arts();
    let exe = a.load_exe("expert_ffn").unwrap();
    let mut rng = Rng::new(5);
    let inputs: Vec<HostTensor> = exe
        .spec
        .inputs
        .iter()
        .map(|s| HostTensor::randn(&s.shape, 0.1, &mut rng))
        .collect();
    let host_out = exe.run(&inputs).unwrap();
    let bufs: Vec<semoe::runtime::executable::DeviceTensor> =
        inputs.iter().map(|t| exe.to_device(t).unwrap()).collect();
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|d| &d.buffer).collect();
    let buf_out = exe.run_buffers(&refs).unwrap();
    assert_eq!(host_out, buf_out);
}
