//! Integration: run the real `semoe lint` passes over this checkout.
//!
//! Two invariants the tier1 gate depends on:
//!
//! 1. With the checked-in allowlist, the tree lints clean (what
//!    `semoe lint` asserts in `scripts/tier1.sh`).
//! 2. Without the allowlist, the only findings are the known, justified
//!    positional-addressing sites — and each anchors to a real file:line
//!    whose text still contains the reported snippet, so diagnostics never
//!    point at stale locations.

use semoe::analysis::{self, contract, load_allowlist, run_all, Tree};

fn repo() -> std::path::PathBuf {
    analysis::repo_root().expect("repo root (set SEMOE_REPO when running from elsewhere)")
}

#[test]
fn tree_lints_clean_with_checked_in_allowlist() {
    let root = repo();
    let report = analysis::lint_repo(&root).unwrap();
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.render()).collect();
    assert!(report.diagnostics.is_empty(), "expected a clean tree, got:\n{}", rendered.join("\n"));
    assert!(report.suppressed > 0, "the allowlist should be suppressing the known ADDR001 sites");
}

#[test]
fn without_allowlist_only_known_positional_sites_fire() {
    let root = repo();
    let tree = Tree::load(&root).unwrap();
    let report = run_all(&tree, &[]);
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.render()).collect();
    assert!(
        report.diagnostics.iter().all(|d| d.rule == contract::RULE_POSITIONAL_INDEX),
        "only ADDR001 may fire un-allowlisted:\n{}",
        rendered.join("\n")
    );
    // The two justified families: head_grad unpacking in the trainer and
    // the per-device PJRT result layout in the executable.
    for d in &report.diagnostics {
        assert!(
            d.file.ends_with("rust/src/train/trainer.rs")
                || d.file.ends_with("rust/src/runtime/executable.rs"),
            "unexpected positional site: {}",
            d.render()
        );
    }
    assert!(!report.diagnostics.is_empty(), "the known sites should fire without the allowlist");

    // Every anchor must resolve: the file exists, the line is in range, and
    // the line's text still matches the diagnostic's snippet.
    for d in &report.diagnostics {
        let path = root.join(&d.file);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("anchor file {} unreadable: {}", d.file, e));
        let lines: Vec<&str> = text.lines().collect();
        assert!(d.line >= 1 && d.line <= lines.len(), "line out of range: {}", d.render());
        assert_eq!(lines[d.line - 1].trim(), d.snippet, "stale anchor: {}", d.render());
    }
}

#[test]
fn allowlist_parses_and_every_entry_is_used() {
    let root = repo();
    let allow = load_allowlist(&root).unwrap();
    assert!(!allow.is_empty(), "lint_allow.txt should carry the justified ADDR001 entries");
    for e in &allow {
        assert!(!e.justification.is_empty());
    }
    // run_all turns unused entries into ALLOW001 findings; a clean report
    // (checked above) therefore implies every entry matched something.
    let tree = Tree::load(&root).unwrap();
    let report = run_all(&tree, &allow);
    assert!(
        !report.diagnostics.iter().any(|d| d.rule == analysis::RULE_STALE_ALLOW),
        "stale allowlist entries present"
    );
}
