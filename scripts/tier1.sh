#!/usr/bin/env bash
# Tier-1 gate: build + unit/integration tests + a smoke run of the
# serving path (examples/serve_ring_inference against the ServeSession
# engine). Run from anywhere; CI runs this on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — install the Rust toolchain" >&2
    exit 1
fi

echo "== tier1: cargo build --release"
cargo build --release

echo "== tier1: cargo test -q"
cargo test -q

echo "== tier1: clippy (deny warnings)"
cargo clippy -q --all-targets -- -D warnings

echo "== tier1: semoe lint (contract drift, thread discipline, metrics coverage)"
cargo run --release -- lint

echo "== tier1: serving smoke (continuous-batching HTTP path, routed ring passes)"
cargo run --release --example serve_ring_inference -- --requests 8 --ring 3 --tokens 2 --routed

echo "== tier1: pipelined serving smoke (layer_dense overlaps the expert copy lane)"
cargo run --release --example serve_ring_inference -- --requests 8 --ring 3 --tokens 2 --routed --pipeline

echo "== tier1: admission-queue property + ring stress regression tests (smoke)"
SEMOE_SMOKE=1 cargo test -q prop_admission_queue_invariants
SEMOE_SMOKE=1 cargo test -q stress_aborted_routed_and_slow_passes

echo "== tier1: pipelined-pass regression (bit-identity to fused, zero tail re-runs, slow-copy-lane overlap)"
cargo test -q pipelined_ring_decode_matches_fused_bitwise
cargo test -q pipelined_steps_match_fused_and_never_rerun_tails
SEMOE_SMOKE=1 cargo test -q slow_copy_lane_pipelined_stalls_less_than_fused

echo "== tier1: artifact-contract regression (v1/v2 manifests → actionable rebuild error)"
cargo test -q contract_v1_manifest_is_actionable
cargo test -q contract_v2_manifest_is_rejected_with_rebuild_message
cargo test -q missing_output_names_the_remedy

echo "== tier1: tail-only repair regression (contract v3: no full-layer re-runs)"
cargo test -q forced_misses_repair_via_expert_tail_bitwise
cargo test -q plan_miss_repairs_execute_only_the_expert_tail

echo "== tier1: expert-parallel bit-identity regression (dist walk == single-host, both hot paths)"
cargo test -q dist_generate_matches_single_host_bitwise
cargo test -q dist_expert_parallel_training_is_bit_identical_to_single_host

echo "== tier1: expert-parallel CLI smoke (2 workers, mesh dispatch, poisonable barrier)"
cargo run --release -- infer --workers 2 --preset tiny --tokens 2
cargo run --release -- train --workers 2 --offload --preset tiny --steps 2

echo "== tier1: token-dispatch CLI smoke (activations to expert owners; auto votes per layer)"
cargo run --release -- infer --workers 2 --preset tiny --tokens 2 --dispatch tokens
cargo run --release -- infer --workers 2 --preset tiny --tokens 2 --dispatch auto
cargo run --release -- train --workers 2 --offload --preset tiny --steps 2 --dispatch tokens

echo "== tier1: expert-parallel decode bench smoke (workers x a2a x skew table, rank0 bitwise invariant)"
SEMOE_SMOKE=1 cargo bench --bench fig11_hierarchical_a2a

echo "== tier1: checkpoint crash-injection suite (randomized fault points, resume bit-equality)"
SEMOE_SMOKE=1 cargo test -q --test checkpoint_crash

echo "== tier1: checkpoint/resume CLI smoke (train → checkpoint → resume → verify)"
CKPT_DIR="$(mktemp -d)"
cargo run --release -- train --offload --preset tiny --steps 4 --checkpoint-dir "$CKPT_DIR" --checkpoint-every 2
cargo run --release -- checkpoint --checkpoint-dir "$CKPT_DIR"
cargo run --release -- train --offload --preset tiny --steps 6 --checkpoint-dir "$CKPT_DIR"
cargo run --release -- checkpoint --checkpoint-dir "$CKPT_DIR"
rm -rf "$CKPT_DIR"

echo "== tier1: python-side layer contract check (v3: split + composition bit-identity)"
if python3 -c "import jax" >/dev/null 2>&1; then
    (cd python && python3 -m pytest tests/test_contract.py tests/test_cost_model.py -q)
else
    echo "tier1: jax unavailable — skipping python contract check" >&2
    # The cost-model mirror is pure python (no jax): always runs.
    (cd python && python3 -m pytest tests/test_cost_model.py -q)
fi

echo "== tier1: 2D-prefetch ablation smoke (asserts 2D < 1D bytes under skew, v2 planner < v1 shadow cost, v3 tail rerun < v2 full-layer rerun)"
SEMOE_SMOKE=1 cargo bench --bench ablation_prefetch

echo "== tier1: routed-vs-dense ring ablation smoke (asserts routed < dense bytes under skew)"
SEMOE_SMOKE=1 cargo bench --bench fig10_ring_offload
SEMOE_SMOKE=1 cargo bench --bench table2_inference

echo "== tier1: perf trajectory stub (BENCH_tier1.json + BENCH_trajectory.json from the smoke reports)"
cargo run --release -- perf-stub
if [ ! -s BENCH_tier1.json ]; then
    echo "tier1: BENCH_tier1.json missing or empty after perf-stub — the snapshot must be written unconditionally" >&2
    exit 1
fi
if [ ! -s BENCH_trajectory.json ]; then
    echo "tier1: BENCH_trajectory.json missing or empty after perf-stub — the trajectory must be seeded even from smoke-only reports" >&2
    exit 1
fi
if ! grep -q dist_token_dispatch_tokens_per_s BENCH_trajectory.json; then
    echo "tier1: dist_token_dispatch_tokens_per_s missing from BENCH_trajectory.json — perf-stub must track the token-dispatch lane (null when the bench has not run)" >&2
    exit 1
fi

echo "== tier1: perf regression gate (tokens/s vs previous trajectory point, >10% drop fails)"
cargo run --release -- perf-compare

echo "tier1 OK"
