//! Quickstart: train a small MoE LM for a few steps, then run greedy
//! generation with the trained weights path (resident mode).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the three-layer architecture end to end: the Pallas/JAX
//! compute is in `artifacts/small/*.hlo.txt`; everything executing here
//! is rust + PJRT.

use std::rc::Rc;

use semoe::config::train::TrainConfig;
use semoe::infer::{InferMode, InferenceEngine, ServeSession, SessionConfig};
use semoe::metrics::Registry;
use semoe::runtime::ModelArtifacts;
use semoe::train::ResidentTrainer;
use semoe::util::human_count;

fn main() -> anyhow::Result<()> {
    let arts = Rc::new(ModelArtifacts::load("small")?);
    let m = arts.preset.clone();
    println!(
        "SE-MoE quickstart — preset '{}': {} params, {} layers × {} experts, capacity {}",
        m.name,
        human_count(m.param_counts().total as u64),
        m.n_layers,
        m.n_experts,
        m.expert_capacity()
    );

    // ---- Train for 30 steps on the synthetic bigram corpus.
    let cfg = TrainConfig { preset: "small".into(), steps: 30, lr: 2e-3, ..Default::default() };
    let mut trainer = ResidentTrainer::new(arts.clone(), cfg.clone())?;
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut last = None;
    for step in 0..cfg.steps {
        let sm = trainer.step()?;
        if step == 0 {
            first = Some(sm.clone());
        }
        if step % 5 == 0 || step + 1 == cfg.steps {
            println!(
                "  step {:>3}  loss {:.4}  ce {:.4}  aux {:.3}",
                sm.step, sm.loss, sm.ce, sm.aux
            );
        }
        last = Some(sm);
    }
    let (first, last) = (first.unwrap(), last.unwrap());
    let secs = t0.elapsed().as_secs_f64();
    let tokens = cfg.steps * m.tokens_per_batch();
    println!(
        "trained {} steps ({} tokens) in {:.1}s → {:.0} tokens/s; loss {:.3} → {:.3}",
        cfg.steps,
        tokens,
        secs,
        tokens as f64 / secs,
        first.loss,
        last.loss
    );
    assert!(last.loss < first.loss, "training must reduce loss");

    // ---- Generate through the continuous-batching ServeSession (same
    // init seed → same weights family; a production flow would load the
    // checkpoint instead). More requests than slots, mixed lengths: the
    // session admits into freed slots between decode steps.
    let engine = InferenceEngine::new(arts.clone(), InferMode::Resident, cfg.seed, None)?;
    let mut session = ServeSession::new(engine, SessionConfig::default(), Registry::new());
    let n_requests = m.batch_size + 2;
    for i in 0..n_requests {
        let prompt = vec![3 * i as i32 + 1; 4];
        session.submit(i as u64 + 1, prompt, 4 + (i % 3) * 2)?; // 4, 6 or 8 tokens
    }
    let mut done = session.run_to_idle()?;
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), n_requests);
    for c in &done {
        println!(
            "  completion #{}: {:?}  ({}; queue {:.1}ms prefill {:.1}ms decode {:.1}ms)",
            c.id,
            c.tokens,
            c.finish.as_str(),
            c.queue.as_secs_f64() * 1e3,
            c.prefill.as_secs_f64() * 1e3,
            c.decode.as_secs_f64() * 1e3
        );
        assert!(c.tokens.iter().all(|&t| t >= 0 && (t as usize) < m.vocab_size));
    }
    let s = session.stats();
    println!(
        "slot schedule: {} decode steps, {} live slot-steps, {} padded",
        s.steps, s.slot_steps, s.padded_slot_steps
    );
    println!("quickstart OK");
    Ok(())
}
