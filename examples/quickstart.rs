//! Quickstart: train a small MoE LM for a few steps, then run greedy
//! generation with the trained weights path (resident mode).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the three-layer architecture end to end: the Pallas/JAX
//! compute is in `artifacts/small/*.hlo.txt`; everything executing here
//! is rust + PJRT.

use std::rc::Rc;

use semoe::config::train::TrainConfig;
use semoe::infer::{InferMode, InferenceEngine};
use semoe::runtime::ModelArtifacts;
use semoe::train::ResidentTrainer;
use semoe::util::human_count;

fn main() -> anyhow::Result<()> {
    let arts = Rc::new(ModelArtifacts::load("small")?);
    let m = arts.preset.clone();
    println!(
        "SE-MoE quickstart — preset '{}': {} params, {} layers × {} experts, capacity {}",
        m.name,
        human_count(m.param_counts().total as u64),
        m.n_layers,
        m.n_experts,
        m.expert_capacity()
    );

    // ---- Train for 30 steps on the synthetic bigram corpus.
    let cfg = TrainConfig { preset: "small".into(), steps: 30, lr: 2e-3, ..Default::default() };
    let mut trainer = ResidentTrainer::new(arts.clone(), cfg.clone())?;
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut last = None;
    for step in 0..cfg.steps {
        let sm = trainer.step()?;
        if step == 0 {
            first = Some(sm.clone());
        }
        if step % 5 == 0 || step + 1 == cfg.steps {
            println!(
                "  step {:>3}  loss {:.4}  ce {:.4}  aux {:.3}",
                sm.step, sm.loss, sm.ce, sm.aux
            );
        }
        last = Some(sm);
    }
    let (first, last) = (first.unwrap(), last.unwrap());
    let secs = t0.elapsed().as_secs_f64();
    let tokens = cfg.steps * m.tokens_per_batch();
    println!(
        "trained {} steps ({} tokens) in {:.1}s → {:.0} tokens/s; loss {:.3} → {:.3}",
        cfg.steps,
        tokens,
        secs,
        tokens as f64 / secs,
        first.loss,
        last.loss
    );
    assert!(last.loss < first.loss, "training must reduce loss");

    // ---- Generate with a fresh engine (same init seed → same weights
    // family; a production flow would load the checkpoint instead).
    let mut engine = InferenceEngine::new(arts.clone(), InferMode::Resident, cfg.seed, None)?;
    let prompt: Vec<Vec<i32>> = (0..m.batch_size).map(|i| vec![3 * i as i32 + 1; 4]).collect();
    let out = engine.generate(&prompt, 8)?;
    for (i, row) in out.iter().enumerate() {
        println!("  generated[{}]: {:?}", i, row);
    }
    println!("quickstart OK");
    Ok(())
}
