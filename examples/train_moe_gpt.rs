//! End-to-end validation driver (DESIGN.md §End-to-end validation):
//! train the ~100M-parameter `base` MoE transformer (4 layers × 48
//! experts, d=256) for a few hundred steps on the synthetic Zipf+bigram
//! corpus through the full stack, logging the loss curve and writing
//! `reports/e2e_train_moe_gpt.{md,json}` for EXPERIMENTS.md.
//!
//!     cargo run --release --example train_moe_gpt -- --steps 300
//!
//! Flags: --steps N (default 200), --lr F (1e-3), --preset P (base),
//!        --resident (fused-train_step trainer instead of the default
//!        hierarchical-offload trainer), --ckpt DIR.

use std::rc::Rc;

use semoe::config::train::TrainConfig;
use semoe::metrics::Report;
use semoe::runtime::ModelArtifacts;
use semoe::train::{checkpoint, OffloadTrainer, ResidentTrainer, SyntheticCorpus};
use semoe::util::cli::Args;
use semoe::util::human_count;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!(e))?;
    let preset = args.str("preset", "base");
    let steps = args.usize("steps", 200);
    let lr = args.f64("lr", 1e-3);
    // The offload trainer IS the paper's system (§2) — and on this
    // substrate it is also the fast path: the fused train_step keeps
    // AdamW inside XLA 0.5.1, which executes elementwise ops ~13x
    // slower than the coordinator's CPU-Adam (EXPERIMENTS.md §Perf).
    let offload = !args.flag("resident");

    let arts = Rc::new(ModelArtifacts::load(&preset)?);
    let m = arts.preset.clone();
    let total = m.param_counts().total;
    println!(
        "e2e training: preset '{}' — {} params ({}% sparse), {} layers × {} experts, \
         batch {}×{} tokens, {} steps [{}]",
        m.name,
        human_count(total as u64),
        (100 * m.sparse_params()) / total,
        m.n_layers,
        m.n_experts,
        m.batch_size,
        m.seq_len,
        steps,
        if offload { "offload" } else { "resident" }
    );

    let cfg = TrainConfig {
        preset: preset.clone(),
        steps,
        lr,
        // Pipelined split sweeps (offload only): layer_dense runs while
        // the planned expert fetches drain — bit-identical to fused.
        pipelined: args.flag("pipeline"),
        log_every: 10,
        ..Default::default()
    };

    let corpus_floor = SyntheticCorpus::new(m.vocab_size, cfg.corpus_skew, 0).entropy_floor();
    let mut curve: Vec<(usize, f32, f32)> = Vec::new();
    let t0 = std::time::Instant::now();
    let mut tokens = 0usize;

    let run = |curve: &mut Vec<(usize, f32, f32)>, tokens: &mut usize| -> anyhow::Result<(f32, f32)> {
        let mut first_loss = f32::NAN;
        let mut last_loss = f32::NAN;
        if offload {
            let mut tr = OffloadTrainer::new(arts.clone(), cfg.clone(), None)?;
            for s in 0..steps {
                let sm = tr.step()?;
                *tokens += sm.tokens;
                if s == 0 {
                    first_loss = sm.loss;
                }
                last_loss = sm.loss;
                if s % cfg.log_every == 0 || s + 1 == steps {
                    println!("  step {:>4}  loss {:.4}  ce {:.4}  aux {:.3}", sm.step, sm.loss, sm.ce, sm.aux);
                    curve.push((sm.step, sm.loss, sm.ce));
                }
            }
            tr.flush()?;
        } else {
            let mut tr = ResidentTrainer::new(arts.clone(), cfg.clone())?;
            for s in 0..steps {
                let sm = tr.step()?;
                *tokens += sm.tokens;
                if s == 0 {
                    first_loss = sm.loss;
                }
                last_loss = sm.loss;
                if s % cfg.log_every == 0 || s + 1 == steps {
                    println!("  step {:>4}  loss {:.4}  ce {:.4}  aux {:.3}", sm.step, sm.loss, sm.ce, sm.aux);
                    curve.push((sm.step, sm.loss, sm.ce));
                }
            }
            if let Some(dir) = args.get("ckpt") {
                checkpoint::save(std::path::Path::new(dir), &arts, tr.params())?;
                println!("checkpoint saved to {}", dir);
            }
        }
        Ok((first_loss, last_loss))
    };

    let (first_loss, last_loss) = run(&mut curve, &mut tokens)?;
    let secs = t0.elapsed().as_secs_f64();
    let tps = tokens as f64 / secs;
    println!(
        "\n{} tokens in {:.1}s → {:.0} tokens/s; loss {:.3} → {:.3} (ln V = {:.3}, generator floor ≈ {:.2})",
        tokens,
        secs,
        tps,
        first_loss,
        last_loss,
        (m.vocab_size as f64).ln(),
        corpus_floor
    );
    assert!(
        last_loss < first_loss - 0.5,
        "e2e run must show a real learning signal"
    );

    // ---- Report for EXPERIMENTS.md.
    let mut rep = Report::new("e2e_train_moe_gpt");
    let t = rep.table(
        "loss curve",
        &["step", "loss", "ce"],
    );
    for (s, loss, ce) in &curve {
        rep.row(t, vec![s.to_string(), format!("{:.4}", loss), format!("{:.4}", ce)]);
    }
    let s = rep.table("summary", &["metric", "value"]);
    rep.row(s, vec!["params".into(), human_count(total as u64)]);
    rep.row(s, vec!["steps".into(), steps.to_string()]);
    rep.row(s, vec!["tokens/s".into(), format!("{:.0}", tps)]);
    rep.row(s, vec!["first loss".into(), format!("{:.4}", first_loss)]);
    rep.row(s, vec!["final loss".into(), format!("{:.4}", last_loss)]);
    rep.row(s, vec!["ln(vocab)".into(), format!("{:.4}", (m.vocab_size as f64).ln())]);
    rep.note(&format!("trainer = {}", if offload { "offload (hierarchical storage + 2D prefetch)" } else { "resident (fused train_step)" }));
    rep.save(std::path::Path::new("reports"))?;
    println!("report written to reports/e2e_train_moe_gpt.md");
    Ok(())
}
