//! Elastic multi-task (UFO-style) training demo (§4.1, Table 3): four
//! tasks with imbalanced batches share a backbone; compare the
//! one-GPU-per-task placement against the elastic plan, running REAL
//! per-task training steps (tiny preset, batch-scaled step cost) under
//! the synchronous cask-effect barrier.
//!
//!     cargo run --release --example elastic_multitask

use std::rc::Rc;

use semoe::config::presets::table3_setup;
use semoe::config::train::TrainConfig;
use semoe::runtime::ModelArtifacts;
use semoe::train::{ElasticPlan, ResidentTrainer, TaskLoad};

fn main() -> anyhow::Result<()> {
    let setup = table3_setup();
    let tasks: Vec<TaskLoad> = setup
        .task_batches
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskLoad { name: format!("task{}", i + 1), batch: b })
        .collect();

    println!("UFO multi-task loads: {:?}", setup.task_batches);

    // ---- Plans.
    let base = ElasticPlan::one_per_task(&tasks);
    let balanced = ElasticPlan::balance(&tasks, 8);
    println!("\nplacements:");
    println!("  imbalanced (fig 6a): gpus/task {:?}  imbalance {:.2}", base.gpus_per_task, base.imbalance());
    println!("  elastic    (fig 6c): gpus/task {:?}  imbalance {:.2}", balanced.gpus_per_task, balanced.imbalance());
    assert_eq!(balanced.gpus_per_task, setup.balanced_gpus_per_task);

    // ---- Measure a real per-sample step cost with the tiny model, then
    // price both placements with the synchronous-barrier model.
    let arts = Rc::new(ModelArtifacts::load("tiny")?);
    let mut tr = ResidentTrainer::new(arts.clone(), TrainConfig { preset: "tiny".into(), steps: 4, ..Default::default() })?;
    let _ = tr.step()?; // warmup/compile
    let t0 = std::time::Instant::now();
    let reps = 3;
    for _ in 0..reps {
        let _ = tr.step()?;
    }
    let per_step = t0.elapsed().as_secs_f64() / reps as f64;
    let per_sample = per_step / arts.preset.batch_size as f64;
    println!("\nmeasured step cost: {:.1} ms/step → {:.2} ms/sample (tiny preset)", per_step * 1e3, per_sample * 1e3);

    // ---- Cask-effect throughput under both plans.
    let (tot_b, per_b) = base.throughput(per_sample);
    let (tot_e, per_e) = balanced.throughput(per_sample);
    println!("\n{:<22} {:>8} {:>14} {:>16}", "placement", "gpus", "samples/s", "per-card");
    println!("{:<22} {:>8} {:>14.1} {:>16.1}", "load imbalance", base.total_gpus(), tot_b, per_b);
    println!("{:<22} {:>8} {:>14.1} {:>16.1}", "elastic (balanced)", balanced.total_gpus(), tot_e, per_e);
    let gain = (per_e / per_b - 1.0) * 100.0;
    println!("\nper-card speedup: +{:.1}%  (paper Table 3: +18.2%)", gain);
    println!(
        "paper reference: {:.1} → {:.1} samples/s/card",
        setup.paper_imbalanced_speed_per_card, setup.paper_balanced_speed_per_card
    );
    assert!(gain > 0.0);
    println!("elastic_multitask OK");
    Ok(())
}
