//! Serving demo: load the 12-layer `deep` model with ring-memory offload
//! (K slots on device, weights on the CPU tier), serve continuous-batching
//! greedy generation over HTTP — per-token slot scheduling, mixed-length
//! requests, slots refilled between decode steps — fire concurrent
//! clients, and report latency percentiles + throughput + slot-occupancy
//! accounting from /stats.
//!
//!     cargo run --release --example serve_ring_inference -- --requests 12 --ring 3

use std::rc::Rc;
use std::sync::mpsc::channel;
use std::sync::Arc;

use semoe::infer::server::{http_get, http_post, Server, ServerStats};
use semoe::infer::{
    AdmissionConfig, InferMode, InferenceEngine, PipelineConfig, RoutedRingConfig, SessionConfig,
};
use semoe::runtime::ModelArtifacts;
use semoe::util::cli::Args;
use semoe::util::human_bytes;
use semoe::util::stats::Percentiles;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!(e))?;
    let preset = args.str("preset", "deep");
    let ring = args.usize("ring", 3);
    let routed = args.flag("routed");
    let pipeline = args.flag("pipeline");
    let n_requests = args.usize("requests", 12);
    let max_tokens = args.usize("tokens", 4);

    // The model factory runs on the server's compute thread (PJRT is
    // thread-confined); it reports the Fig-10 memory numbers back here.
    let (info_tx, info_rx) = channel::<(usize, usize)>();
    let stats = Arc::new(ServerStats::default());
    let preset_owned = preset.clone();
    let server = Server::start(
        "127.0.0.1:0",
        SessionConfig {
            admission: AdmissionConfig {
                max_queue: 256,
                linger: std::time::Duration::from_millis(2),
            },
        },
        stats.clone(),
        move || {
            let arts = Rc::new(ModelArtifacts::load(&preset_owned)?);
            let mode = if ring > 0 { InferMode::Ring { k: ring } } else { InferMode::Resident };
            let mut engine = InferenceEngine::new(arts.clone(), mode, 7, None)?;
            if routed && ring > 0 {
                engine.set_routed(RoutedRingConfig { enabled: true, hot_frac: 0.5 });
            }
            if pipeline && ring > 0 {
                engine.set_pipelined(PipelineConfig { enabled: true, hot_frac: 0.5 });
            }
            let resident = InferenceEngine::new(arts.clone(), InferMode::Resident, 7, None)?;
            let _ = info_tx.send((engine.device_weight_bytes(), resident.device_weight_bytes()));
            drop(resident);
            Ok(engine)
        },
    )?;
    let addr = server.addr;
    println!(
        "serving '{}' with ring K={}{}{} on {}",
        preset,
        ring,
        if routed { " (routed passes)" } else { "" },
        if pipeline { " (pipelined passes)" } else { "" },
        addr
    );

    let (code, h) = http_get(&addr, "/healthz")?;
    assert_eq!(code, 200);
    assert_eq!(h.get("ok").as_bool(), Some(true));

    // ---- fire concurrent clients with MIXED generation lengths: the
    // continuous-batching engine retires short requests immediately and
    // refills their slots while long ones keep decoding.
    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..n_requests)
        .map(|i| {
            std::thread::spawn(move || {
                let want = 1 + (i % 3) * max_tokens.max(1); // 1, 1+m, 1+2m …
                let body = format!(
                    r#"{{"prompt": [{}, {}, {}], "max_tokens": {}}}"#,
                    i, i + 1, i + 2, want
                );
                let t = std::time::Instant::now();
                let out = http_post(&addr, "/generate", &body);
                (out, want, t.elapsed().as_secs_f64())
            })
        })
        .collect();
    let mut lat = Percentiles::new();
    let mut queue_ms = Percentiles::new();
    let mut tokens_out = 0usize;
    for c in clients {
        let (out, want, secs) = c.join().unwrap();
        let (code, j) = out?;
        assert_eq!(code, 200, "{}", j);
        let got = j.get("tokens").as_arr().map(|a| a.len()).unwrap_or(0);
        assert_eq!(got, want, "each request gets exactly its own budget");
        assert_eq!(j.get("finish").as_str(), Some("length"));
        tokens_out += got;
        queue_ms.add(j.get("queue_ms").as_f64().unwrap_or(0.0));
        lat.add(secs * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();

    let (dev_ring, dev_res) = info_rx.recv()?;
    let (_, s) = http_get(&addr, "/stats")?;
    drop(server); // graceful: drains slots, joins threads

    println!("\n=== serving report (continuous batching) ===");
    println!("requests: {}  tokens out: {}  wall: {:.2}s  → {:.1} tokens/s",
        n_requests, tokens_out, wall, tokens_out as f64 / wall);
    println!("latency ms: p50 {:.0}  p95 {:.0}  p99 {:.0}   queue-wait ms: p50 {:.1}  p95 {:.1}",
        lat.p50(), lat.p95(), lat.p99(), queue_ms.p50(), queue_ms.p95());
    let steps = s.get("steps").as_f64().unwrap_or(0.0);
    let slot_steps = s.get("slot_steps").as_f64().unwrap_or(0.0);
    let padded = s.get("padded_slot_steps").as_f64().unwrap_or(0.0);
    println!("slot schedule: {} decode steps, {} live slot-steps, {} padded ({:.0}% utilization)",
        steps, slot_steps, padded, 100.0 * slot_steps / (slot_steps + padded).max(1.0));
    println!("device weights: ring {} vs resident {} ({:.0}% saved)",
        human_bytes(dev_ring as u64), human_bytes(dev_res as u64),
        100.0 * (1.0 - dev_ring as f64 / dev_res as f64));
    // Routed-pass accounting straight from /stats (published by the
    // engine after every decode step — docs/serving.md §Observability).
    let g = |k: &str| s.get(k).as_f64().unwrap_or(0.0);
    println!(
        "route plan: {:.0} planned / {:.0} exact / {:.0} repaired experts, \
         {:.0} tail reruns ({:.0} full-layer), {:.0} carried plans; ring copy lane {:.1} MB",
        g("route_planned_experts"), g("route_exact_experts"), g("route_repaired_experts"),
        g("route_rerun_tails"), g("route_rerun_layers"), g("route_carried_plans"),
        g("ring_copy_bytes") / 1e6
    );
    // Contract v3 / PR-4 ROADMAP item: planner + tail-repair timing
    // surfaced end to end (engine → gauges → /stats → here).
    println!(
        "route timing: plan {:.2} ms, tail reruns {:.2} ms",
        g("plan_ms"), g("tail_rerun_ms")
    );
    assert_eq!(
        g("route_rerun_layers"), 0.0,
        "contract v3: plan-miss repairs must be tail-only"
    );
    if pipeline && ring > 0 {
        // PR-7: pipelined split-pass accounting end to end.
        println!(
            "pipelined passes: {:.0} dense-prefix layers, overlap {:.2} ms, stalled {:.2} ms",
            g("route_dense_prefix_layers"), g("overlap_ms"), g("stalled_ms")
        );
        assert!(
            g("route_dense_prefix_layers") > 0.0,
            "pipelined serving must run layer_dense on every section"
        );
        assert_eq!(
            g("route_rerun_tails"), 0.0,
            "pipelined passes are exact by construction — no tail reruns"
        );
    }
    println!("serve_ring_inference OK");
    Ok(())
}
