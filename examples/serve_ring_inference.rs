//! Serving demo: load the 12-layer `deep` model with ring-memory offload
//! (K slots on device, weights on the CPU tier), serve batched greedy
//! generation over HTTP, fire concurrent client requests, and report
//! latency percentiles + throughput + the ring's overlap accounting.
//!
//!     cargo run --release --example serve_ring_inference -- --requests 12 --ring 3

use std::rc::Rc;
use std::sync::mpsc::channel;
use std::sync::Arc;

use semoe::infer::server::{http_get, http_post, Server, ServerStats};
use semoe::infer::{BatcherConfig, InferMode, InferenceEngine, Request};
use semoe::runtime::ModelArtifacts;
use semoe::util::cli::Args;
use semoe::util::human_bytes;
use semoe::util::stats::Percentiles;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!(e))?;
    let preset = args.str("preset", "deep");
    let ring = args.usize("ring", 3);
    let n_requests = args.usize("requests", 12);
    let max_tokens = args.usize("tokens", 4);

    // ---- model thread (PJRT is thread-confined)
    let (req_tx, req_rx) = channel::<(Vec<Request>, std::sync::mpsc::Sender<Vec<Vec<i32>>>)>();
    let preset_owned = preset.clone();
    let model_thread = std::thread::spawn(move || -> anyhow::Result<(usize, usize, f64, f64, f64)> {
        let arts = Rc::new(ModelArtifacts::load(&preset_owned)?);
        let mode = if ring > 0 { InferMode::Ring { k: ring } } else { InferMode::Resident };
        let mut engine = InferenceEngine::new(arts.clone(), mode, 7, None)?;
        let resident = InferenceEngine::new(arts.clone(), InferMode::Resident, 7, None)?;
        let dev_ring = engine.device_weight_bytes();
        let dev_res = resident.device_weight_bytes();
        drop(resident);
        while let Ok((reqs, reply)) = req_rx.recv() {
            if reqs.is_empty() {
                break; // shutdown signal
            }
            let b = engine.arts.preset.batch_size;
            let mut prompts: Vec<Vec<i32>> = reqs.iter().map(|r| r.prompt.clone()).collect();
            prompts.resize(b, Vec::new());
            let max_new = reqs.iter().map(|r| r.max_tokens).max().unwrap_or(1);
            let gen = engine.generate(&prompts, max_new)?;
            let out = reqs
                .iter()
                .enumerate()
                .map(|(i, r)| gen[i][..r.max_tokens.min(gen[i].len())].to_vec())
                .collect();
            let _ = reply.send(out);
        }
        Ok((
            dev_ring,
            dev_res,
            engine.timing.compute_secs,
            engine.timing.copy_secs,
            engine.timing.stall_secs,
        ))
    });

    let stats = Arc::new(ServerStats::default());
    let req_tx_srv = req_tx.clone();
    let server = Server::start(
        "127.0.0.1:0",
        BatcherConfig { batch_size: 4, linger: std::time::Duration::from_millis(10) },
        stats.clone(),
        move |reqs| {
            let (tx, rx) = channel();
            let _ = req_tx_srv.send((reqs.to_vec(), tx));
            rx.recv().unwrap_or_default()
        },
    )?;
    let addr = server.addr;
    println!("serving '{}' with ring K={} on {}", preset, ring, addr);

    let (code, h) = http_get(&addr, "/healthz")?;
    assert_eq!(code, 200);
    assert_eq!(h.get("ok").as_bool(), Some(true));

    // ---- fire concurrent clients
    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..n_requests)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": [{}, {}, {}], "max_tokens": {}}}"#,
                    i, i + 1, i + 2, max_tokens
                );
                let t = std::time::Instant::now();
                let out = http_post(&addr, "/generate", &body);
                (out, t.elapsed().as_secs_f64())
            })
        })
        .collect();
    let mut lat = Percentiles::new();
    let mut tokens_out = 0usize;
    for c in clients {
        let (out, secs) = c.join().unwrap();
        let (code, j) = out?;
        assert_eq!(code, 200, "{}", j);
        tokens_out += j.get("tokens").as_arr().map(|a| a.len()).unwrap_or(0);
        lat.add(secs * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- shutdown the model thread, collect timing
    let (tx, _rx) = channel();
    let _ = req_tx.send((Vec::new(), tx));
    let (dev_ring, dev_res, compute, copy, stall) = model_thread.join().unwrap()?;
    drop(server);

    println!("\n=== serving report ===");
    println!("requests: {}  tokens out: {}  wall: {:.2}s  → {:.1} tokens/s",
        n_requests, tokens_out, wall, tokens_out as f64 / wall);
    println!("latency ms: p50 {:.0}  p95 {:.0}  p99 {:.0}", lat.p50(), lat.p95(), lat.p99());
    println!("device weights: ring {} vs resident {} ({:.0}% saved)",
        human_bytes(dev_ring as u64), human_bytes(dev_res as u64),
        100.0 * (1.0 - dev_ring as f64 / dev_res as f64));
    println!("engine: compute {:.2}s  copy {:.2}s  stall {:.2}s (un-hidden {:.0}%)",
        compute, copy, stall, 100.0 * stall / copy.max(1e-9));
    println!("serve_ring_inference OK");
    Ok(())
}
