"""L2 building blocks: layernorm, fused-MHA block, switching-FFN MoE block.

Parameters are plain lists of jnp arrays in a FIXED order (see
`LAYER_PARAM_NAMES`); the AOT manifest records the order so the rust
coordinator can slice fused parameter buffers back into per-tensor
literals (the paper's "parameter management unit", §2.3).
"""

import jax
import jax.numpy as jnp

from . import kernels as K
from .configs import MoEConfig

# Per-decoder-layer parameter order. `sparse` marks expert (selectively
# activated) tensors — the hierarchical store places those on the SSD tier.
LAYER_PARAM_NAMES = [
    ("ln1_scale", False), ("ln1_bias", False),
    ("wq", False), ("bq", False), ("wk", False), ("bk", False),
    ("wv", False), ("bv", False), ("wo", False), ("bo", False),
    ("ln2_scale", False), ("ln2_bias", False),
    ("router_w", False), ("router_b", False),
    ("w1", True), ("b1", True), ("w2", True), ("b2", True),
]

N_LAYER_PARAMS = len(LAYER_PARAM_NAMES)

# The dense prefix owns the first N_DENSE_PARAMS entries (ln1 → MHA →
# ln2 → router); the expert tail owns the trailing sparse four
# (w1/b1/w2/b2). Contract v3 splits the layer artifacts at exactly this
# boundary.
N_DENSE_PARAMS = sum(1 for _, sp in LAYER_PARAM_NAMES if not sp)


def layer_param_shapes(cfg: MoEConfig):
    """[(name, shape, is_sparse)] for one decoder layer."""
    h, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    shapes = {
        "ln1_scale": (h,), "ln1_bias": (h,),
        "wq": (h, h), "bq": (h,), "wk": (h, h), "bk": (h,),
        "wv": (h, h), "bv": (h,), "wo": (h, h), "bo": (h,),
        "ln2_scale": (h,), "ln2_bias": (h,),
        "router_w": (h, e), "router_b": (e,),
        "w1": (e, h, f), "b1": (e, f), "w2": (e, f, h), "b2": (e, h),
    }
    return [(n, shapes[n], s) for n, s in LAYER_PARAM_NAMES]


def layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def mha_block(cfg: MoEConfig, x, wq, bq, wk, bk, wv, bv, wo, bo):
    """Multi-head attention with the fused pallas core. x: [B,T,H]."""
    B, T, H = x.shape
    N, Dh = cfg.n_heads, cfg.d_head

    def split(y):
        return y.reshape(B, T, N, Dh).transpose(0, 2, 1, 3)  # [B,N,T,Dh]

    q = split(x @ wq + bq)
    k = split(x @ wk + bk)
    v = split(x @ wv + bv)
    o = K.attention(q, k, v)                     # pallas fused MHA
    o = o.transpose(0, 2, 1, 3).reshape(B, T, H)
    return o @ wo + bo


def dense_prefix(cfg: MoEConfig, x, dense_params):
    """The layer's dense half: ln1 → causal MHA → residual → ln2 → router.

    `dense_params` is the first `N_DENSE_PARAMS` entries of the layer
    list (everything but the expert tensors). Returns
    `(h, moe_in, aux, expert, gate, pos, keep)`:

    - `h [B,T,H]`       post-attention residual hidden (the value the
                        MoE output is added onto),
    - `moe_in [B,T,H]`  ln2-normalized `h` — the dispatch input,
    - `aux` scalar      load-balancing loss (depends only on the gate),
    - `expert [B,T] i32`, `gate [B,T] f32`, `pos [B,T] i32`,
      `keep [B,T] f32`  the full per-token routing decision (argmax
                        expert, its kept softmax prob, capacity slot,
                        keep mask).

    None of these depend on the expert weights — the property every
    repair path (contract v2's splice-and-rerun, contract v3's
    tail-only re-execution) is built on.
    """
    (ln1_s, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_s, ln2_b, rw, rb) = dense_params
    B, T, H = x.shape
    a = mha_block(cfg, layer_norm(x, ln1_s, ln1_b), wq, bq, wk, bk, wv, bv, wo, bo)
    h = x + a
    moe_in = layer_norm(h, ln2_s, ln2_b)
    logits = moe_in.reshape(B * T, H) @ rw + rb          # [BT, E]
    expert, gate, pos, keep, me, ce = K.top1_gating(logits, cfg.expert_capacity)
    aux = K.ref.aux_loss_ref(me, ce)
    return (h, moe_in, aux, expert.reshape(B, T), gate.reshape(B, T),
            pos.reshape(B, T), keep.reshape(B, T))


def expert_tail(cfg: MoEConfig, h, moe_in, expert, gate, pos, keep,
                w1, b1, w2, b2):
    """The layer's sparse half: dispatch → expert FFN → gated combine →
    residual. Parameterized by ONLY the expert weights; everything else
    arrives as activations from [`dense_prefix`] (or as the equivalent
    `layer_fwd` outputs). Returns `y [B,T,H]` — the layer output.

    Re-executing this with repaired expert weights is bit-identical to
    re-running the whole layer: the dense prefix is deterministic in
    `x`, and unrouted experts' buffers are never read by the one-hot
    combine.
    """
    B, T, H = h.shape
    E, C = cfg.n_experts, cfg.expert_capacity
    flat_e, flat_g = expert.reshape(B * T), gate.reshape(B * T)
    flat_p, flat_k = pos.reshape(B * T), keep.reshape(B * T)
    buf = K.dispatch(moe_in.reshape(B * T, H), flat_e, flat_p, flat_k, E, C)
    y_buf = K.expert_ffn(buf, w1, b1, w2, b2)            # pallas hot spot
    m = K.combine(y_buf, flat_e, flat_p, flat_k, flat_g)  # [BT,H]
    return h + m.reshape(B, T, H)


def moe_block(cfg: MoEConfig, x, router_w, router_b, w1, b1, w2, b2):
    """Switching-FFN over an already-normalized input: top-1 gate ->
    dispatch -> grouped FFN -> combine (no residual).

    Returns (y [B,T,H], aux_loss scalar, expert [B,T] i32, gate [B,T] f32).
    Kept as the standalone MoE surface for tests; the layer entry points
    compose [`dense_prefix`] and [`expert_tail`] instead.
    """
    B, T, H = x.shape
    E, C = cfg.n_experts, cfg.expert_capacity
    flat = x.reshape(B * T, H)
    logits = flat @ router_w + router_b          # [BT, E]
    expert, gate, pos, keep, me, ce = K.top1_gating(logits, C)
    buf = K.dispatch(flat, expert, pos, keep, E, C)      # [E,C,H]
    y_buf = K.expert_ffn(buf, w1, b1, w2, b2)            # pallas hot spot
    y = K.combine(y_buf, expert, pos, keep, gate)        # [BT,H]
    aux = K.ref.aux_loss_ref(me, ce)
    return (y.reshape(B, T, H), aux,
            expert.reshape(B, T), gate.reshape(B, T))


def decoder_layer_split(cfg: MoEConfig, x, layer_params):
    """One pre-norm decoder block as the dense ∘ tail composition —
    the contract-v3 `layer_fwd` output set.

    Returns (y, aux, expert, gate, pos, keep, h, moe_in). The fused
    artifact and the split `layer_dense`/`expert_tail` artifacts lower
    the SAME jaxpr pieces, so `layer_dense ∘ expert_tail ≡ layer_fwd`
    bit for bit (asserted by `tests/test_contract.py`).
    """
    dense, sparse = layer_params[:N_DENSE_PARAMS], layer_params[N_DENSE_PARAMS:]
    h, moe_in, aux, expert, gate, pos, keep = dense_prefix(cfg, x, dense)
    y = expert_tail(cfg, h, moe_in, expert, gate, pos, keep, *sparse)
    return y, aux, expert, gate, pos, keep, h, moe_in


def decoder_layer_routed(cfg: MoEConfig, x, layer_params):
    """One pre-norm decoder block, routing decisions included.

    Returns (y [B,T,H], aux_loss scalar, expert [B,T] i32, gate [B,T] f32).
    """
    y, aux, expert, gate, _, _, _, _ = decoder_layer_split(cfg, x, layer_params)
    return y, aux, expert, gate


def decoder_layer(cfg: MoEConfig, x, layer_params):
    """One pre-norm decoder block. layer_params: list in LAYER_PARAM_NAMES order.

    Returns (y [B,T,H], aux_loss scalar). The routing outputs are dropped
    (XLA prunes the dead int32 path); fused entries (`train_step`,
    `fwd_loss`, `layer_bwd`'s vjp) differentiate through this form.
    """
    y, aux, _, _ = decoder_layer_routed(cfg, x, layer_params)
    return y, aux
