"""L2 building blocks: layernorm, fused-MHA block, switching-FFN MoE block.

Parameters are plain lists of jnp arrays in a FIXED order (see
`LAYER_PARAM_NAMES`); the AOT manifest records the order so the rust
coordinator can slice fused parameter buffers back into per-tensor
literals (the paper's "parameter management unit", §2.3).
"""

import jax
import jax.numpy as jnp

from . import kernels as K
from .configs import MoEConfig

# Per-decoder-layer parameter order. `sparse` marks expert (selectively
# activated) tensors — the hierarchical store places those on the SSD tier.
LAYER_PARAM_NAMES = [
    ("ln1_scale", False), ("ln1_bias", False),
    ("wq", False), ("bq", False), ("wk", False), ("bk", False),
    ("wv", False), ("bv", False), ("wo", False), ("bo", False),
    ("ln2_scale", False), ("ln2_bias", False),
    ("router_w", False), ("router_b", False),
    ("w1", True), ("b1", True), ("w2", True), ("b2", True),
]

N_LAYER_PARAMS = len(LAYER_PARAM_NAMES)


def layer_param_shapes(cfg: MoEConfig):
    """[(name, shape, is_sparse)] for one decoder layer."""
    h, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    shapes = {
        "ln1_scale": (h,), "ln1_bias": (h,),
        "wq": (h, h), "bq": (h,), "wk": (h, h), "bk": (h,),
        "wv": (h, h), "bv": (h,), "wo": (h, h), "bo": (h,),
        "ln2_scale": (h,), "ln2_bias": (h,),
        "router_w": (h, e), "router_b": (e,),
        "w1": (e, h, f), "b1": (e, f), "w2": (e, f, h), "b2": (e, h),
    }
    return [(n, shapes[n], s) for n, s in LAYER_PARAM_NAMES]


def layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def mha_block(cfg: MoEConfig, x, wq, bq, wk, bk, wv, bv, wo, bo):
    """Multi-head attention with the fused pallas core. x: [B,T,H]."""
    B, T, H = x.shape
    N, Dh = cfg.n_heads, cfg.d_head

    def split(y):
        return y.reshape(B, T, N, Dh).transpose(0, 2, 1, 3)  # [B,N,T,Dh]

    q = split(x @ wq + bq)
    k = split(x @ wk + bk)
    v = split(x @ wv + bv)
    o = K.attention(q, k, v)                     # pallas fused MHA
    o = o.transpose(0, 2, 1, 3).reshape(B, T, H)
    return o @ wo + bo


def moe_block(cfg: MoEConfig, x, router_w, router_b, w1, b1, w2, b2):
    """Switching-FFN: top-1 gate -> dispatch -> grouped FFN -> combine.

    Returns (y [B,T,H], aux_loss scalar, expert [B,T] i32, gate [B,T] f32).

    `expert`/`gate` are the per-token routing decisions (contract-v2
    "kernel-emitted routed set"): `expert[t]` is the argmax expert of
    token t — valid whatever the expert weights hold, since the router
    logits depend only on the dense prefix — and `gate[t]` is the
    softmax probability of that expert, zeroed for capacity-dropped
    tokens (the gating kernel's `gate * keep`).
    """
    B, T, H = x.shape
    E, C = cfg.n_experts, cfg.expert_capacity
    flat = x.reshape(B * T, H)
    logits = flat @ router_w + router_b          # [BT, E]
    expert, gate, pos, keep, me, ce = K.top1_gating(logits, C)
    buf = K.dispatch(flat, expert, pos, keep, E, C)      # [E,C,H]
    y_buf = K.expert_ffn(buf, w1, b1, w2, b2)            # pallas hot spot
    y = K.combine(y_buf, expert, pos, keep, gate)        # [BT,H]
    aux = K.ref.aux_loss_ref(me, ce)
    return (y.reshape(B, T, H), aux,
            expert.reshape(B, T), gate.reshape(B, T))


def decoder_layer_routed(cfg: MoEConfig, x, layer_params):
    """One pre-norm decoder block, routing decisions included.

    Returns (y [B,T,H], aux_loss scalar, expert [B,T] i32, gate [B,T] f32)
    — the contract-v2 `layer_fwd` output set.
    """
    (ln1_s, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_s, ln2_b, rw, rb, w1, b1, w2, b2) = layer_params
    a = mha_block(cfg, layer_norm(x, ln1_s, ln1_b), wq, bq, wk, bk, wv, bv, wo, bo)
    x = x + a
    m, aux, expert, gate = moe_block(
        cfg, layer_norm(x, ln2_s, ln2_b), rw, rb, w1, b1, w2, b2)
    return x + m, aux, expert, gate


def decoder_layer(cfg: MoEConfig, x, layer_params):
    """One pre-norm decoder block. layer_params: list in LAYER_PARAM_NAMES order.

    Returns (y [B,T,H], aux_loss scalar). The routing outputs are dropped
    (XLA prunes the dead int32 path); fused entries (`train_step`,
    `fwd_loss`, `layer_bwd`'s vjp) differentiate through this form.
    """
    y, aux, _, _ = decoder_layer_routed(cfg, x, layer_params)
    return y, aux
