"""Model/preset configuration shared by the L2 model and the AOT pipeline.

Presets are mirrored in `rust/src/config/presets.rs`; the AOT pipeline also
emits `artifacts/<preset>/manifest.json` so the rust side never hard-codes
shapes — it reads them from the manifest at load time.
"""

from dataclasses import dataclass, asdict, field


@dataclass(frozen=True)
class MoEConfig:
    """Switch-Transformer style decoder-only MoE LM.

    Every decoder block is: LN -> fused MHA -> residual -> LN -> MoE FFN
    (top-1 gated switching FFN, GShard capacity) -> residual.
    """

    name: str
    vocab_size: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    n_experts: int
    seq_len: int
    batch_size: int
    capacity_factor: float = 2.0
    aux_loss_weight: float = 1e-2
    # AdamW hyperparameters baked into the train_step artifact.
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def tokens_per_batch(self) -> int:
        return self.batch_size * self.seq_len

    @property
    def expert_capacity(self) -> int:
        """GShard capacity: ceil(cf * tokens / experts)."""
        t = self.tokens_per_batch
        return max(1, -(-int(self.capacity_factor * t) // self.n_experts))

    def param_counts(self) -> dict:
        """Parameter counts by group (mirrors rust config::model)."""
        h, f, e, v = self.d_model, self.d_ff, self.n_experts, self.vocab_size
        attn = 4 * h * h + 4 * h  # qkvo + biases
        ln = 4 * h  # two layernorms (scale+bias each)
        router = h * e + e
        experts = e * (h * f + f + f * h + h)
        per_layer = attn + ln + router + experts
        embed = v * h
        head = h * v + 2 * h  # final ln + output proj (untied)
        total = embed + self.n_layers * per_layer + head
        return {
            "embed": embed,
            "per_layer": per_layer,
            "per_layer_dense": attn + ln + router,
            "per_layer_sparse": experts,
            "head": head,
            "total": total,
        }

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        d["expert_capacity"] = self.expert_capacity
        d["param_counts"] = self.param_counts()
        return d


# ---------------------------------------------------------------------------
# Presets. `tiny` is the unit-test scale; `small` is quickstart/integration;
# `deep` exercises the ring-memory offload path (many layers, small width);
# `base` is the ~100M end-to-end training target (params live in experts, so
# top-1 gating keeps the compute laptop-scale while the state is 100M+).
# ---------------------------------------------------------------------------

PRESETS = {
    "tiny": MoEConfig(
        name="tiny", vocab_size=256, d_model=64, n_heads=4, n_layers=2,
        d_ff=256, n_experts=4, seq_len=32, batch_size=4,
    ),
    "small": MoEConfig(
        name="small", vocab_size=1024, d_model=128, n_heads=4, n_layers=2,
        d_ff=512, n_experts=8, seq_len=32, batch_size=4,
    ),
    "deep": MoEConfig(
        name="deep", vocab_size=1024, d_model=128, n_heads=4, n_layers=12,
        d_ff=512, n_experts=8, seq_len=32, batch_size=4,
    ),
    "base": MoEConfig(
        name="base", vocab_size=4096, d_model=256, n_heads=8, n_layers=4,
        d_ff=1024, n_experts=48, seq_len=64, batch_size=4,
    ),
}


def get_config(name: str) -> MoEConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
