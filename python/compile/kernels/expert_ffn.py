"""Pallas grouped expert-FFN kernel — the switching-FFN hot spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA expert FFN
launches one threadblock per (expert, token-tile) and keeps the expert's
weights in shared memory across its token loop. Here the *grid* is the
expert axis: each grid cell streams one expert's W1/W2 tile HBM->VMEM via
BlockSpec and runs both matmuls + GELU on the whole capacity block while
the tile is resident — MXU-shaped (H, F multiples of 128 at real scale),
fp32 accumulation via preferred_element_type, mirroring MXU semantics.

VMEM per grid cell (f32): C*H + H*F + F + C*F + F*H + H + C*H bytes*4.
For the `base` preset (C=11->pad, H=256, F=1024): ~2.4 MB — well under
the ~16 MB VMEM budget; DESIGN.md §Perf records the estimate per preset.

The backward is also a Pallas kernel (same grid layout): recompute the
hidden activation in-cell and produce dX, dW1, db1, dW2, db2. This is the
recompute-in-backward (per-layer checkpointing) strategy the offloading
runtime uses anyway, so nothing extra is saved between passes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


_SQRT_2_OVER_PI = 0.7978845608028654


def _gelu(x):
    # tanh-approximation GELU, matching jax.nn.gelu(approximate=True).
    return 0.5 * x * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x ** 3)))


def _gelu_grad(x):
    t = jnp.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x ** 3))
    dt = (1.0 - t ** 2) * _SQRT_2_OVER_PI * (1.0 + 3 * 0.044715 * x ** 2)
    return 0.5 * (1.0 + t) + 0.5 * x * dt


def _ffn_fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[0]            # [C, H] — this expert's token slots
    w1 = w1_ref[0]          # [H, F]
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1_ref[0]
    h = _gelu(h)
    o_ref[0] = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32) + b2_ref[0]


def expert_ffn_pallas(x_buf, w1, b1, w2, b2):
    """Grouped FFN forward. x_buf [E,C,H] -> [E,C,H]."""
    E, C, H = x_buf.shape
    F = w1.shape[-1]
    return pl.pallas_call(
        _ffn_fwd_kernel,
        grid=(E,),
        in_specs=[
            pl.BlockSpec((1, C, H), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, H, F), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, F), lambda e: (e, 0)),
            pl.BlockSpec((1, F, H), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, H), lambda e: (e, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, H), lambda e: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, H), jnp.float32),
        interpret=True,
    )(x_buf, w1, b1, w2, b2)


def _ffn_bwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, dy_ref,
                    dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref):
    x = x_ref[0]           # [C, H]
    w1 = w1_ref[0]         # [H, F]
    w2 = w2_ref[0]         # [F, H]
    dy = dy_ref[0]         # [C, H]
    # Recompute pre-activation (checkpointing: nothing saved from fwd).
    z = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1_ref[0]
    h = _gelu(z)
    dh = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    dz = dh * _gelu_grad(z)
    dx_ref[0] = jnp.dot(dz, w1.T, preferred_element_type=jnp.float32)
    dw1_ref[0] = jnp.dot(x.T, dz, preferred_element_type=jnp.float32)
    db1_ref[0] = jnp.sum(dz, axis=0)
    dw2_ref[0] = jnp.dot(h.T, dy, preferred_element_type=jnp.float32)
    db2_ref[0] = jnp.sum(dy, axis=0)


def expert_ffn_bwd_pallas(x_buf, w1, b1, w2, dy):
    """Grouped FFN backward (pallas). Returns (dx, dw1, db1, dw2, db2)."""
    E, C, H = x_buf.shape
    F = w1.shape[-1]
    out_shape = (
        jax.ShapeDtypeStruct((E, C, H), jnp.float32),
        jax.ShapeDtypeStruct((E, H, F), jnp.float32),
        jax.ShapeDtypeStruct((E, F), jnp.float32),
        jax.ShapeDtypeStruct((E, F, H), jnp.float32),
        jax.ShapeDtypeStruct((E, H), jnp.float32),
    )
    return pl.pallas_call(
        _ffn_bwd_kernel,
        grid=(E,),
        in_specs=[
            pl.BlockSpec((1, C, H), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, H, F), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, F), lambda e: (e, 0)),
            pl.BlockSpec((1, F, H), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, C, H), lambda e: (e, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, C, H), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, H, F), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, F), lambda e: (e, 0)),
            pl.BlockSpec((1, F, H), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, H), lambda e: (e, 0)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(x_buf, w1, b1, w2, dy)


# ---------------------------------------------------------------------------
# Fused (gridless) variants.
#
# Pallas's interpret mode emulates each grid cell over full-sized blocks,
# so an E-cell grid costs ~E× the math on CPU — pathological for E=48.
# The fused variants run ONE kernel instance whose body is the batched
# einsum over all experts; on real TPU the gridded version above is the
# right shape (per-expert VMEM tiles), on CPU-interpret the fused one is.
# The dispatcher below picks per `E` (see _GRID_MAX_EXPERTS); numerical
# equivalence is asserted in python/tests/test_expert_ffn.py.
# ---------------------------------------------------------------------------

_GRID_MAX_EXPERTS = 8


def _ffn_fwd_fused_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    h = jnp.einsum("ech,ehf->ecf", x_ref[...], w1_ref[...],
                   preferred_element_type=jnp.float32) + b1_ref[...][:, None, :]
    h = _gelu(h)
    o_ref[...] = jnp.einsum("ecf,efh->ech", h, w2_ref[...],
                            preferred_element_type=jnp.float32) + b2_ref[...][:, None, :]


def expert_ffn_pallas_fused(x_buf, w1, b1, w2, b2):
    """Gridless grouped FFN forward (interpret-friendly)."""
    E, C, H = x_buf.shape
    return pl.pallas_call(
        _ffn_fwd_fused_kernel,
        out_shape=jax.ShapeDtypeStruct((E, C, H), jnp.float32),
        interpret=True,
    )(x_buf, w1, b1, w2, b2)


def _ffn_bwd_fused_kernel(x_ref, w1_ref, b1_ref, w2_ref, dy_ref,
                          dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref):
    x = x_ref[...]
    w1 = w1_ref[...]
    w2 = w2_ref[...]
    dy = dy_ref[...]
    z = jnp.einsum("ech,ehf->ecf", x, w1,
                   preferred_element_type=jnp.float32) + b1_ref[...][:, None, :]
    h = _gelu(z)
    dh = jnp.einsum("ech,efh->ecf", dy, w2, preferred_element_type=jnp.float32)
    dz = dh * _gelu_grad(z)
    dx_ref[...] = jnp.einsum("ecf,ehf->ech", dz, w1, preferred_element_type=jnp.float32)
    dw1_ref[...] = jnp.einsum("ech,ecf->ehf", x, dz, preferred_element_type=jnp.float32)
    db1_ref[...] = jnp.sum(dz, axis=1)
    dw2_ref[...] = jnp.einsum("ecf,ech->efh", h, dy, preferred_element_type=jnp.float32)
    db2_ref[...] = jnp.sum(dy, axis=1)


def expert_ffn_bwd_pallas_fused(x_buf, w1, b1, w2, dy):
    """Gridless grouped FFN backward."""
    E, C, H = x_buf.shape
    F = w1.shape[-1]
    out_shape = (
        jax.ShapeDtypeStruct((E, C, H), jnp.float32),
        jax.ShapeDtypeStruct((E, H, F), jnp.float32),
        jax.ShapeDtypeStruct((E, F), jnp.float32),
        jax.ShapeDtypeStruct((E, F, H), jnp.float32),
        jax.ShapeDtypeStruct((E, H), jnp.float32),
    )
    return pl.pallas_call(
        _ffn_bwd_fused_kernel,
        out_shape=out_shape,
        interpret=True,
    )(x_buf, w1, b1, w2, dy)


def _fwd_dispatch(x_buf, w1, b1, w2, b2):
    if x_buf.shape[0] <= _GRID_MAX_EXPERTS:
        return expert_ffn_pallas(x_buf, w1, b1, w2, b2)
    return expert_ffn_pallas_fused(x_buf, w1, b1, w2, b2)


def _bwd_dispatch(x_buf, w1, b1, w2, dy):
    if x_buf.shape[0] <= _GRID_MAX_EXPERTS:
        return expert_ffn_bwd_pallas(x_buf, w1, b1, w2, dy)
    return expert_ffn_bwd_pallas_fused(x_buf, w1, b1, w2, dy)


@jax.custom_vjp
def expert_ffn(x_buf, w1, b1, w2, b2):
    """Differentiable grouped expert FFN (pallas fwd + pallas bwd)."""
    return _fwd_dispatch(x_buf, w1, b1, w2, b2)


def _fwd(x_buf, w1, b1, w2, b2):
    return _fwd_dispatch(x_buf, w1, b1, w2, b2), (x_buf, w1, b1, w2)


def _bwd(res, dy):
    x_buf, w1, b1, w2 = res
    dx, dw1, db1, dw2, db2 = _bwd_dispatch(x_buf, w1, b1, w2, dy)
    return dx, dw1, db1, dw2, db2


expert_ffn.defvjp(_fwd, _bwd)
