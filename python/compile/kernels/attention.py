"""Pallas fused causal multi-head attention kernel.

This is the MLPerf-style fused attention §3.1 cites ("Fused Multi-head
Attention ... effective to reduce kernel launch time"). Hardware
adaptation (DESIGN.md §Hardware-Adaptation): instead of CUDA's
three-kernel QK^T / softmax / PV pipeline staged through shared memory,
one Pallas grid cell per (batch, head) holds the full [T, T] score tile
in VMEM — at our sequence lengths (T <= 512) that is <= 1 MB, far under
the ~16 MB VMEM budget — and applies scale, causal mask, softmax and the
value matmul in-register. This is the TPU-idiomatic fusion point; a
flash-style streaming split over T only pays off once T*T*4B outgrows
VMEM.

Backward: custom_vjp recomputes probabilities in the backward kernel
(checkpointing — nothing saved but q,k,v) and emits dq, dk, dv; also a
single fused Pallas kernel over the same grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


_NEG = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0, 0]  # [T, Dh]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    T, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    ti = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    s = jnp.where(ti >= tj, s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def _specs(B, N, T, Dh):
    return pl.BlockSpec((1, 1, T, Dh), lambda b, n: (b, n, 0, 0))


def attention_pallas(q, k, v):
    """Fused causal MHA forward. q,k,v: [B,N,T,Dh] -> [B,N,T,Dh]."""
    B, N, T, Dh = q.shape
    spec = _specs(B, N, T, Dh)
    return pl.pallas_call(
        _attn_fwd_kernel,
        grid=(B, N),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, N, T, Dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0]
    T, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    ti = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    s = jnp.where(ti >= tj, s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)      # [T, T] recomputed probs
    dv_ref[0, 0] = jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    # Softmax VJP: ds = p * (dp - sum(dp * p, axis=-1))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = ds * scale
    dq_ref[0, 0] = jnp.dot(ds, k, preferred_element_type=jnp.float32)
    dk_ref[0, 0] = jnp.dot(ds.T, q, preferred_element_type=jnp.float32)


def attention_bwd_pallas(q, k, v, do):
    B, N, T, Dh = q.shape
    spec = _specs(B, N, T, Dh)
    shape = jax.ShapeDtypeStruct((B, N, T, Dh), jnp.float32)
    return pl.pallas_call(
        _attn_bwd_kernel,
        grid=(B, N),
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec, spec),
        out_shape=(shape, shape, shape),
        interpret=True,
    )(q, k, v, do)


@jax.custom_vjp
def attention(q, k, v):
    """Differentiable fused causal MHA (pallas fwd + pallas bwd)."""
    return attention_pallas(q, k, v)


def _fwd(q, k, v):
    return attention_pallas(q, k, v), (q, k, v)


def _bwd(res, do):
    q, k, v = res
    return attention_bwd_pallas(q, k, v, do)


attention.defvjp(_fwd, _bwd)
