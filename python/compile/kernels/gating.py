"""Pallas top-1 (switch) gating kernel.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the CUDA version
of switch gating uses warp-level reductions for per-token softmax/argmax;
on TPU the VPU wants whole-row vector ops, so this kernel keeps the entire
[T, E] router tile in VMEM (E <= 128 fits one lane group at these scales)
and derives argmax / gate / capacity position with vector selects and a
single cumulative sum down the token axis — no reduction trees.

Differentiability: only the `gate` output carries gradient (through the
softmax); expert/pos/keep are integer routing decisions. The custom_vjp
backward recomputes softmax with jnp (cheap, [T,E]) and propagates
d(gate) and d(me) into d(logits).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _gating_kernel(capacity: int, logits_ref, expert_ref, gate_ref, pos_ref,
                   keep_ref, me_ref, ce_ref):
    logits = logits_ref[...]
    T, E = logits.shape
    # Row softmax in VMEM (VPU-friendly: subtract rowmax, exp, normalize).
    m = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)
    expert = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    # One-hot via broadcasted iota compare (TPU-legal 2D iota).
    iota_e = jax.lax.broadcasted_iota(jnp.int32, (T, E), 1)
    onehot = (expert[:, None] == iota_e).astype(jnp.float32)
    # Arrival-order slot within each expert: cumulative count down tokens.
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1.0
    keep = (pos < capacity).astype(jnp.float32)
    gate = jnp.sum(probs * onehot, axis=-1) * keep

    expert_ref[...] = expert
    gate_ref[...] = gate
    pos_ref[...] = pos.astype(jnp.int32)
    keep_ref[...] = keep
    me_ref[...] = jnp.mean(probs, axis=0)
    ce_ref[...] = jnp.mean(onehot, axis=0)


def top1_gating_pallas(logits: jax.Array, capacity: int):
    """Raw pallas call (fwd only). Shapes/semantics match ref.top1_gating_ref."""
    T, E = logits.shape
    out_shape = (
        jax.ShapeDtypeStruct((T,), jnp.int32),    # expert
        jax.ShapeDtypeStruct((T,), jnp.float32),  # gate
        jax.ShapeDtypeStruct((T,), jnp.int32),    # pos
        jax.ShapeDtypeStruct((T,), jnp.float32),  # keep
        jax.ShapeDtypeStruct((E,), jnp.float32),  # me
        jax.ShapeDtypeStruct((E,), jnp.float32),  # ce
    )
    return pl.pallas_call(
        functools.partial(_gating_kernel, capacity),
        out_shape=out_shape,
        interpret=True,  # CPU-PJRT target; Mosaic lowering is TPU-only.
    )(logits)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def top1_gating(logits: jax.Array, capacity: int):
    """Differentiable top-1 gating (pallas fwd, analytic bwd)."""
    return top1_gating_pallas(logits, capacity)


def _gating_fwd(logits, capacity):
    out = top1_gating_pallas(logits, capacity)
    return out, (logits, out[0], out[3])


def _gating_bwd(capacity, res, cots):
    logits, expert, keep = res
    d_expert, d_gate, d_pos, d_keep, d_me, d_ce = cots
    T, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(expert, E, dtype=jnp.float32)
    # gate = sum(probs * onehot) * keep  ->  d probs = onehot * keep * d_gate
    dprobs = onehot * (keep * d_gate)[:, None]
    # me = mean(probs, axis=0)          ->  d probs += d_me / T
    dprobs = dprobs + d_me[None, :] / T
    # Softmax VJP: dl = probs * (dp - sum(dp * probs))
    dlogits = probs * (dprobs - jnp.sum(dprobs * probs, axis=-1, keepdims=True))
    return (dlogits,)


top1_gating.defvjp(_gating_fwd, _gating_bwd)
