"""L1 Pallas kernels for the SE-MoE compute hot spots.

Every kernel has a pure-jnp oracle in `ref.py`; pytest compares them under
hypothesis-driven shape/seed sweeps. All kernels lower with interpret=True
so the AOT HLO runs on the CPU PJRT client (real-TPU lowering would emit
Mosaic custom-calls the CPU plugin cannot execute).
"""

from . import ref
from .gating import top1_gating, top1_gating_pallas
from .expert_ffn import (
    expert_ffn, expert_ffn_pallas, expert_ffn_bwd_pallas,
    expert_ffn_pallas_fused, expert_ffn_bwd_pallas_fused,
)
from .dispatch import (
    dispatch, dispatch_pallas, dispatch_transpose_pallas,
    combine, combine_pallas,
)
from .attention import attention, attention_pallas, attention_bwd_pallas

__all__ = [
    "ref",
    "top1_gating", "top1_gating_pallas",
    "expert_ffn", "expert_ffn_pallas", "expert_ffn_bwd_pallas",
    "dispatch", "dispatch_pallas", "dispatch_transpose_pallas",
    "combine", "combine_pallas",
    "attention", "attention_pallas", "attention_bwd_pallas",
]
