"""Pallas dispatch/combine kernels — GShard one-hot-matmul token routing.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA
implementation scatters tokens into per-expert buffers with atomics /
indexed copies (the H2D-pinned-memory "unique kernels" of §3.1). A
gather/scatter is hostile to the TPU's vector memory, so we use GShard's
formulation: build the [T, E*C] one-hot dispatch matrix in VMEM from the
routing decisions (iota compare — no scatter) and turn dispatch & combine
into MXU matmuls. Combine additionally folds the gate weighting in.

Both ops are linear in x / y_buf, so their VJPs are the transposed
matmuls with the SAME one-hot matrix — also expressed as pallas calls.
No gradient flows to the integer routing decisions; the gate gradient is
produced by the combine VJP.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot(expert, pos, keep, n_experts, capacity):
    """[T, E*C] dispatch matrix built with vector compares (in-kernel)."""
    T = expert.shape[0]
    slot = expert * capacity + jnp.minimum(pos, capacity - 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (T, n_experts * capacity), 1)
    return (slot[:, None] == iota).astype(jnp.float32) * keep[:, None]


def _dispatch_kernel(n_experts, capacity, x_ref, e_ref, p_ref, k_ref, o_ref):
    oh = _onehot(e_ref[...], p_ref[...], k_ref[...], n_experts, capacity)
    buf = jnp.dot(oh.T, x_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = buf.reshape(n_experts, capacity, x_ref.shape[-1])


def dispatch_pallas(x, expert, pos, keep, n_experts: int, capacity: int):
    """Scatter tokens [T,H] -> per-expert buffers [E,C,H] (pallas)."""
    T, H = x.shape
    return pl.pallas_call(
        functools.partial(_dispatch_kernel, n_experts, capacity),
        out_shape=jax.ShapeDtypeStruct((n_experts, capacity, H), jnp.float32),
        interpret=True,
    )(x, expert, pos, keep)


def _dispatch_t_kernel(n_experts, capacity, buf_ref, e_ref, p_ref, k_ref, o_ref):
    # Transpose of dispatch: tokens get back their (unweighted) slot rows.
    oh = _onehot(e_ref[...], p_ref[...], k_ref[...], n_experts, capacity)
    flat = buf_ref[...].reshape(n_experts * capacity, -1)
    o_ref[...] = jnp.dot(oh, flat, preferred_element_type=jnp.float32)


def dispatch_transpose_pallas(buf, expert, pos, keep):
    """[E,C,H] -> [T,H] unweighted gather; the VJP of dispatch."""
    E, C, H = buf.shape
    T = expert.shape[0]
    return pl.pallas_call(
        functools.partial(_dispatch_t_kernel, E, C),
        out_shape=jax.ShapeDtypeStruct((T, H), jnp.float32),
        interpret=True,
    )(buf, expert, pos, keep)


def _combine_kernel(n_experts, capacity, buf_ref, e_ref, p_ref, k_ref, g_ref, o_ref):
    oh = _onehot(e_ref[...], p_ref[...], k_ref[...], n_experts, capacity)
    oh = oh * g_ref[...][:, None]
    flat = buf_ref[...].reshape(n_experts * capacity, -1)
    o_ref[...] = jnp.dot(oh, flat, preferred_element_type=jnp.float32)


def combine_pallas(y_buf, expert, pos, keep, gate):
    """Gate-weighted gather [E,C,H] -> [T,H] (pallas)."""
    E, C, H = y_buf.shape
    T = expert.shape[0]
    return pl.pallas_call(
        functools.partial(_combine_kernel, E, C),
        out_shape=jax.ShapeDtypeStruct((T, H), jnp.float32),
        interpret=True,
    )(y_buf, expert, pos, keep, gate)


# ---------------------------------------------------------------------------
# Differentiable wrappers.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def dispatch(x, expert, pos, keep, n_experts: int, capacity: int):
    """Differentiable dispatch (linear in x)."""
    return dispatch_pallas(x, expert, pos, keep, n_experts, capacity)


def _dispatch_fwd(x, expert, pos, keep, n_experts, capacity):
    out = dispatch_pallas(x, expert, pos, keep, n_experts, capacity)
    return out, (expert, pos, keep)


def _dispatch_bwd(n_experts, capacity, res, dbuf):
    expert, pos, keep = res
    dx = dispatch_transpose_pallas(dbuf, expert, pos, keep)
    return dx, None, None, None


dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def combine(y_buf, expert, pos, keep, gate):
    """Differentiable combine (linear in y_buf and gate)."""
    return combine_pallas(y_buf, expert, pos, keep, gate)


def _combine_fwd(y_buf, expert, pos, keep, gate):
    return combine_pallas(y_buf, expert, pos, keep, gate), (y_buf, expert, pos, keep, gate)


def _combine_bwd(res, dy):
    y_buf, expert, pos, keep, gate = res
    E, C, H = y_buf.shape
    # d y_buf = dispatch of (gate-weighted dy).
    dbuf = dispatch_pallas(dy * gate[:, None], expert, pos, keep, E, C)
    # d gate[t] = <dy[t], y_buf[slot(t)]> — gather rows then dot.
    rows = dispatch_transpose_pallas(y_buf, expert, pos, keep)
    dgate = jnp.sum(dy * rows, axis=-1)
    return dbuf, None, None, None, dgate


combine.defvjp(_combine_fwd, _combine_bwd)
