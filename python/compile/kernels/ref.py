"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must
match its oracle to float32 tolerance under pytest + hypothesis sweeps
(`python/tests/test_*.py`). They are also the differentiable fallbacks
used inside custom_vjp backward rules where the hot path does not need a
hand-written backward kernel.
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Top-1 (switch) gating — GShard capacity semantics.
# ---------------------------------------------------------------------------

def top1_gating_ref(logits: jax.Array, capacity: int):
    """Reference top-1 gating.

    Args:
      logits: [T, E] router logits.
      capacity: per-expert slot budget C.

    Returns:
      expert:  [T] int32, argmax expert per token.
      gate:    [T] f32, softmax prob of the chosen expert (0 if dropped).
      pos:     [T] int32, slot index within the chosen expert (valid iff kept).
      keep:    [T] f32, 1.0 if the token got a slot (pos < C) else 0.0.
      me:      [E] f32, mean router prob per expert (aux-loss term).
      ce:      [E] f32, fraction of tokens routed per expert (aux-loss term).
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    expert = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(expert, E, dtype=jnp.float32)
    # Position of each token within its expert's arrival order.
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=-1).astype(jnp.int32) - 1
    keep = (pos < capacity).astype(jnp.float32)
    gate = (probs * onehot).sum(axis=-1) * keep
    me = probs.mean(axis=0)
    ce = onehot.mean(axis=0)
    return expert, gate, pos, keep, me, ce


def aux_loss_ref(me: jax.Array, ce: jax.Array) -> jax.Array:
    """Switch-Transformer load-balancing loss: E * sum(me * ce)."""
    return me.shape[0] * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Dispatch / combine — GShard one-hot-matmul formulation.
# ---------------------------------------------------------------------------

def dispatch_onehot_ref(expert, pos, keep, n_experts: int, capacity: int):
    """[T, E*C] one-hot dispatch matrix (f32)."""
    slot = expert * capacity + jnp.minimum(pos, capacity - 1)
    oh = jax.nn.one_hot(slot, n_experts * capacity, dtype=jnp.float32)
    return oh * keep[:, None]


def dispatch_ref(x, expert, pos, keep, n_experts: int, capacity: int):
    """Scatter tokens [T, H] into per-expert buffers [E, C, H]."""
    oh = dispatch_onehot_ref(expert, pos, keep, n_experts, capacity)
    buf = oh.T @ x  # [E*C, H]
    return buf.reshape(n_experts, capacity, -1)


def combine_ref(y_buf, expert, pos, keep, gate):
    """Gather expert outputs [E, C, H] back to tokens [T, H], gate-weighted."""
    E, C, H = y_buf.shape
    oh = dispatch_onehot_ref(expert, pos, keep, E, C)
    return (oh * gate[:, None]) @ y_buf.reshape(E * C, H)


# ---------------------------------------------------------------------------
# Grouped expert FFN (the switching-FFN hot spot).
# ---------------------------------------------------------------------------

def expert_ffn_ref(x_buf, w1, b1, w2, b2):
    """Per-expert FFN: gelu(x @ w1 + b1) @ w2 + b2.

    Shapes: x_buf [E, C, H], w1 [E, H, F], b1 [E, F], w2 [E, F, H], b2 [E, H].
    """
    h = jnp.einsum("ech,ehf->ecf", x_buf, w1) + b1[:, None, :]
    h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efh->ech", h, w2) + b2[:, None, :]


# ---------------------------------------------------------------------------
# Fused causal multi-head attention.
# ---------------------------------------------------------------------------

def attention_ref(q, k, v):
    """Causal MHA core. q,k,v: [B, N, T, Dh] -> [B, N, T, Dh]."""
    B, N, T, Dh = q.shape
    scores = jnp.einsum("bntd,bnsd->bnts", q, k) / jnp.sqrt(Dh).astype(q.dtype)
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bnts,bnsd->bntd", probs, v)
