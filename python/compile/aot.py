"""AOT pipeline: lower every L2 entry point to HLO **text** + manifest.

HLO text (NOT `lowered.compile()` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the HLO text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Outputs, per preset:
  artifacts/<preset>/<entry>.hlo.txt
  artifacts/<preset>/manifest.json     — preset config + per-artifact
                                         input/output names/shapes/dtypes +
                                         the flat parameter layout (the rust
                                         "parameter management unit" reads
                                         this instead of hard-coding shapes)

Idempotent: an artifact is re-lowered only if missing or if the preset
fingerprint changed (`make artifacts` stays a no-op when inputs are
unchanged).

Usage: python -m compile.aot --out-dir ../artifacts [--preset tiny ...]
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import MoEConfig, PRESETS, get_config
from .layers import layer_param_shapes, N_DENSE_PARAMS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, arr_spec):
    dt = {jnp.float32: "f32", jnp.int32: "i32"}[
        jnp.float32 if arr_spec.dtype == jnp.float32 else jnp.int32]
    return {"name": name, "dtype": dt, "shape": list(arr_spec.shape)}


# ---------------------------------------------------------------------------
# Entry-point catalogue. Each entry: fn(cfg) -> (callable, [(name, spec)...],
# [(out_name, spec)...]). The callable takes positional args in input order.
# ---------------------------------------------------------------------------

def _params_specs(cfg, prefix=""):
    return [(prefix + n, _spec(s)) for n, s, _ in M.param_spec(cfg)]


def _layer_specs(cfg, prefix=""):
    return [(prefix + n, _spec(s)) for n, s, _ in layer_param_shapes(cfg)]


def entry_train_step(cfg):
    P = len(M.param_spec(cfg))
    ins = (_params_specs(cfg, "p.")
           + _params_specs(cfg, "m.")
           + _params_specs(cfg, "v.")
           + [("step", _spec((), jnp.float32)), ("lr", _spec((), jnp.float32)),
              ("tokens", _spec((cfg.batch_size, cfg.seq_len), jnp.int32)),
              ("labels", _spec((cfg.batch_size, cfg.seq_len), jnp.int32))])

    def fn(*args):
        params = list(args[:P])
        ms = list(args[P:2 * P])
        vs = list(args[2 * P:3 * P])
        step, lr, tokens, labels = args[3 * P:]
        p2, m2, v2, loss, ce, aux = M.train_step(cfg, params, ms, vs, step, lr,
                                                 tokens, labels)
        return tuple(p2) + tuple(m2) + tuple(v2) + (loss, ce, aux)

    outs = (_params_specs(cfg, "p'.") + _params_specs(cfg, "m'.")
            + _params_specs(cfg, "v'.")
            + [("loss", _spec(())), ("ce", _spec(())), ("aux", _spec(()))])
    return fn, ins, outs


def entry_fwd_loss(cfg):
    P = len(M.param_spec(cfg))
    ins = _params_specs(cfg, "p.") + [
        ("tokens", _spec((cfg.batch_size, cfg.seq_len), jnp.int32)),
        ("labels", _spec((cfg.batch_size, cfg.seq_len), jnp.int32))]

    def fn(*args):
        return M.forward(cfg, list(args[:P]), args[P], args[P + 1])

    outs = [("loss", _spec(())), ("ce", _spec(())), ("aux", _spec(()))]
    return fn, ins, outs


def entry_embed_fwd(cfg):
    B, T, H, V = cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size
    ins = [("tokens", _spec((B, T), jnp.int32)), ("embed", _spec((V, H)))]
    fn = lambda tokens, embed: (M.embed_fwd(tokens, embed),)
    outs = [("x", _spec((B, T, H)))]
    return fn, ins, outs


def entry_embed_bwd(cfg):
    B, T, H, V = cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size
    ins = [("tokens", _spec((B, T), jnp.int32)), ("dx", _spec((B, T, H)))]
    fn = lambda tokens, dx: (M.embed_bwd(tokens, dx, V),)
    outs = [("dembed", _spec((V, H)))]
    return fn, ins, outs


# The routing quadruple every layer entry emits (contract v3): argmax
# expert, its kept softmax prob, capacity slot, keep mask — exactly what
# `expert_tail` consumes.
def _route_specs(cfg):
    B, T = cfg.batch_size, cfg.seq_len
    return [("route_expert", _spec((B, T), jnp.int32)),
            ("route_gate", _spec((B, T))),
            ("route_pos", _spec((B, T), jnp.int32)),
            ("route_keep", _spec((B, T)))]


def entry_layer_fwd(cfg):
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model
    ins = [("x", _spec((B, T, H)))] + _layer_specs(cfg)

    def fn(x, *lps):
        return M.layer_fwd(cfg, x, list(lps))

    # Contract v3: the fused fast path. Besides the v2 routing outputs,
    # the dense-prefix activations (`h`, `moe_in`) ride out so a
    # plan-miss repair can re-execute ONLY `expert_tail` — the rust
    # coordinator addresses everything by name, never by position.
    outs = ([("y", _spec((B, T, H))), ("aux", _spec(()))]
            + _route_specs(cfg)
            + [("h", _spec((B, T, H))), ("moe_in", _spec((B, T, H)))])
    return fn, ins, outs


def entry_layer_dense(cfg):
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model
    ins = [("x", _spec((B, T, H)))] + _layer_specs(cfg)[:N_DENSE_PARAMS]

    def fn(x, *dps):
        return M.layer_dense(cfg, x, list(dps))

    outs = ([("h", _spec((B, T, H))), ("moe_in", _spec((B, T, H))),
             ("aux", _spec(()))] + _route_specs(cfg))
    return fn, ins, outs


def entry_expert_tail(cfg):
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model
    ins = ([("h", _spec((B, T, H))), ("moe_in", _spec((B, T, H)))]
           + _route_specs(cfg)
           + _layer_specs(cfg)[N_DENSE_PARAMS:])

    def fn(h, moe_in, expert, gate, pos, keep, w1, b1, w2, b2):
        return (M.expert_tail(cfg, h, moe_in, expert, gate, pos, keep,
                              w1, b1, w2, b2),)

    outs = [("y", _spec((B, T, H)))]
    return fn, ins, outs


def entry_layer_bwd(cfg):
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model
    nl = len(layer_param_shapes(cfg))
    ins = ([("x", _spec((B, T, H)))] + _layer_specs(cfg)
           + [("dy", _spec((B, T, H))), ("daux", _spec(()))])

    def fn(x, *rest):
        lps = list(rest[:nl])
        dy, daux = rest[nl], rest[nl + 1]
        dx, dps = M.layer_bwd(cfg, x, lps, dy, daux)
        return tuple([dx] + list(dps))

    outs = [("dx", _spec((B, T, H)))] + [
        ("d" + n, s) for n, s in _layer_specs(cfg)]
    return fn, ins, outs


def entry_head_fwd(cfg):
    B, T, H, V = cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size
    ins = [("x", _spec((B, T, H))), ("lnf_scale", _spec((H,))),
           ("lnf_bias", _spec((H,))), ("wout", _spec((H, V))),
           ("labels", _spec((B, T), jnp.int32))]
    fn = lambda x, a, b, w, l: (M.head_fwd(cfg, x, a, b, w, l),)
    outs = [("loss", _spec(()))]
    return fn, ins, outs


def entry_head_grad(cfg):
    B, T, H, V = cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size
    ins = [("x", _spec((B, T, H))), ("lnf_scale", _spec((H,))),
           ("lnf_bias", _spec((H,))), ("wout", _spec((H, V))),
           ("labels", _spec((B, T), jnp.int32))]
    fn = lambda x, a, b, w, l: M.head_grad(cfg, x, a, b, w, l)
    outs = [("loss", _spec(())), ("dx", _spec((B, T, H))),
            ("dlnf_scale", _spec((H,))), ("dlnf_bias", _spec((H,))),
            ("dwout", _spec((H, V)))]
    return fn, ins, outs


def entry_head_infer(cfg):
    B, T, H, V = cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size
    ins = [("x", _spec((B, T, H))), ("lnf_scale", _spec((H,))),
           ("lnf_bias", _spec((H,))), ("wout", _spec((H, V)))]
    fn = lambda x, a, b, w: (M.head_infer(cfg, x, a, b, w),)
    outs = [("next_token", _spec((B,), jnp.int32))]
    return fn, ins, outs


def _entry_adamw(cfg, n):
    ins = [("p", _spec((n,))), ("g", _spec((n,))), ("m", _spec((n,))),
           ("v", _spec((n,))), ("step", _spec(())), ("lr", _spec(()))]

    def fn(p, g, m, v, step, lr):
        return M.adamw_flat(cfg, p, g, m, v, step, lr)

    outs = [("p2", _spec((n,))), ("m2", _spec((n,))), ("v2", _spec((n,)))]
    return fn, ins, outs


def entry_adamw_layer(cfg):
    return _entry_adamw(cfg, cfg.param_counts()["per_layer"])


def entry_adamw_embed(cfg):
    return _entry_adamw(cfg, cfg.param_counts()["embed"])


def entry_adamw_head(cfg):
    return _entry_adamw(cfg, cfg.param_counts()["head"])


# Kernel micro-artifacts (runtime tests + micro-benches against rust).

def entry_gating(cfg):
    from . import kernels as K
    T, E, C = cfg.tokens_per_batch, cfg.n_experts, cfg.expert_capacity
    ins = [("logits", _spec((T, E)))]
    fn = lambda lg: K.top1_gating_pallas(lg, C)
    outs = [("expert", _spec((T,), jnp.int32)), ("gate", _spec((T,))),
            ("pos", _spec((T,), jnp.int32)), ("keep", _spec((T,))),
            ("me", _spec((E,))), ("ce", _spec((E,)))]
    return fn, ins, outs


def entry_expert_ffn(cfg):
    from . import kernels as K
    E, C, H, F = cfg.n_experts, cfg.expert_capacity, cfg.d_model, cfg.d_ff
    ins = [("x_buf", _spec((E, C, H))), ("w1", _spec((E, H, F))),
           ("b1", _spec((E, F))), ("w2", _spec((E, F, H))), ("b2", _spec((E, H)))]
    fn = lambda *a: (K.expert_ffn_pallas(*a),)
    outs = [("y_buf", _spec((E, C, H)))]
    return fn, ins, outs


def entry_attention(cfg):
    from . import kernels as K
    B, N, T, Dh = cfg.batch_size, cfg.n_heads, cfg.seq_len, cfg.d_head
    s = _spec((B, N, T, Dh))
    ins = [("q", s), ("k", s), ("v", s)]
    fn = lambda q, k, v: (K.attention_pallas(q, k, v),)
    outs = [("o", s)]
    return fn, ins, outs


ENTRIES = {
    "train_step": entry_train_step,
    "fwd_loss": entry_fwd_loss,
    "embed_fwd": entry_embed_fwd,
    "embed_bwd": entry_embed_bwd,
    "layer_fwd": entry_layer_fwd,
    "layer_dense": entry_layer_dense,
    "expert_tail": entry_expert_tail,
    "layer_bwd": entry_layer_bwd,
    "head_fwd": entry_head_fwd,
    "head_grad": entry_head_grad,
    "head_infer": entry_head_infer,
    "adamw_layer": entry_adamw_layer,
    "adamw_embed": entry_adamw_embed,
    "adamw_head": entry_adamw_head,
    "gating": entry_gating,
    "expert_ffn": entry_expert_ffn,
    "attention": entry_attention,
}

# Which entries each preset gets. tiny/small get everything (tests);
# deep feeds the ring-memory inference path; base feeds the resident e2e
# trainer plus the offload trainer.
PRESET_ENTRIES = {
    "tiny": list(ENTRIES),
    "small": list(ENTRIES),
    "deep": ["embed_fwd", "layer_fwd", "layer_dense", "expert_tail",
             "head_infer", "head_fwd", "gating", "expert_ffn", "attention"],
    "base": ["train_step", "fwd_loss", "embed_fwd", "embed_bwd", "layer_fwd",
             "layer_dense", "expert_tail", "layer_bwd", "head_grad",
             "head_infer", "adamw_layer", "adamw_embed", "adamw_head"],
}


AOT_CODE_VERSION = 4  # bump to force re-lowering after kernel changes

# The artifact *contract* version: what the rust coordinator may assume
# about entry-point signatures. v3 = the layer splits at the
# dense/sparse boundary: `layer_fwd` (the fused fast path) emits the
# routing quadruple (`route_expert`/`route_gate`/`route_pos`/
# `route_keep`) AND the dense-prefix activations (`h`, `moe_in`), and
# the `layer_dense`/`expert_tail` pair exists so a plan-miss repair
# re-executes only the MoE block. The rust side
# (`runtime/registry.rs::CONTRACT_VERSION`) refuses mismatched manifests
# with a "rebuild artifacts" error instead of shape-panicking mid-run.
CONTRACT_VERSION = 3


def _fingerprint(cfg: MoEConfig, entry: str) -> str:
    blob = json.dumps({"cfg": cfg.to_dict(), "entry": entry, "v": AOT_CODE_VERSION}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def lower_preset(preset: str, out_dir: str, only=None, force=False, verbose=True):
    cfg = get_config(preset)
    pdir = os.path.join(out_dir, preset)
    os.makedirs(pdir, exist_ok=True)
    mpath = os.path.join(pdir, "manifest.json")
    manifest = {"preset": cfg.to_dict(), "artifacts": {}, "params": []}
    if os.path.exists(mpath):
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except Exception:
            pass
    # A manifest written under another contract version drops all its
    # artifact entries before the stamp below, so a manifest can never
    # claim v2 while still listing v1 artifacts — even if this run is
    # interrupted mid-lowering.
    if manifest.get("contract_version") != CONTRACT_VERSION:
        manifest["artifacts"] = {}
    manifest["contract_version"] = CONTRACT_VERSION
    manifest["preset"] = cfg.to_dict()
    manifest["params"] = [
        {"name": n, "shape": list(s), "sparse": sp,
         "numel": int(__import__("numpy").prod(s)) if s else 1}
        for n, s, sp in M.param_spec(cfg)]

    entries = PRESET_ENTRIES[preset] if only is None else only
    for entry in entries:
        fp = _fingerprint(cfg, entry)
        fname = f"{entry}.hlo.txt"
        fpath = os.path.join(pdir, fname)
        prev = manifest["artifacts"].get(entry)
        if (not force and prev and prev.get("fingerprint") == fp
                and os.path.exists(fpath)):
            continue
        t0 = time.time()
        fn, ins, outs = ENTRIES[entry](cfg)
        lowered = jax.jit(fn).lower(*[s for _, s in ins])
        text = to_hlo_text(lowered)
        with open(fpath, "w") as f:
            f.write(text)
        manifest["artifacts"][entry] = {
            "file": fname,
            "fingerprint": fp,
            "inputs": [_io(n, s) for n, s in ins],
            "outputs": [_io(n, s) for n, s in outs],
        }
        if verbose:
            print(f"[aot] {preset}/{entry}: {len(text)} chars "
                  f"({time.time() - t0:.1f}s)")
        # Persist incrementally so an interrupted run resumes.
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", action="append", default=None,
                    help="preset(s) to lower; default: all")
    ap.add_argument("--entry", action="append", default=None,
                    help="restrict to specific entries")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    presets = args.preset or list(PRESET_ENTRIES)
    for p in presets:
        lower_preset(p, args.out_dir, only=args.entry, force=args.force)


if __name__ == "__main__":
    main()
