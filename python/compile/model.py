"""L2: the MoE-GPT model — fwd/bwd/step entry points that get AOT-lowered.

The model is a decoder-only LM with a switching-FFN MoE in every block
(Switch-Transformer layout). Parameters travel as a FLAT LIST of arrays
in a fixed order (see `param_spec`) so the HLO artifact argument order is
deterministic and the rust coordinator can address tensors by index.

Entry points (each becomes one HLO artifact; see aot.py):
  train_step     fused fwd+bwd+AdamW over all params (resident training)
  fwd_loss       forward + loss (eval)
  embed_fwd/bwd  embedding lookup and its gradient (one-hot matmul)
  layer_fwd/bwd  single decoder layer; fwd also emits the per-token
                 routing decisions AND the dense-prefix activations
                 (contract v3); bwd recomputes fwd (checkpointing)
  layer_dense    the layer's dense half alone (ln1 → MHA → residual →
                 ln2 → router/gating) — no expert weights in its
                 signature
  expert_tail    the layer's sparse half alone (dispatch → expert FFN →
                 gated combine → residual) — only expert weights in its
                 signature; re-executed on plan-miss repairs
  head_fwd       final LN + logits + loss
  head_grad      head loss + gradients (dx and head param grads)
  head_infer     greedy next-token ids
  adamw_flat     elementwise AdamW on a fused 1-D parameter group
"""

import jax
import jax.numpy as jnp

from . import kernels as K
from .configs import MoEConfig
from .layers import (decoder_layer, decoder_layer_split, dense_prefix,
                     expert_tail as _expert_tail, layer_norm,
                     layer_param_shapes, N_LAYER_PARAMS)


# ---------------------------------------------------------------------------
# Parameter layout.
# ---------------------------------------------------------------------------

def param_spec(cfg: MoEConfig):
    """Flat [(name, shape, is_sparse)] in artifact argument order."""
    v, h = cfg.vocab_size, cfg.d_model
    spec = [("embed", (v, h), False)]
    for i in range(cfg.n_layers):
        for n, s, sp in layer_param_shapes(cfg):
            spec.append((f"layer{i}.{n}", s, sp))
    spec += [("lnf_scale", (h,), False), ("lnf_bias", (h,), False),
             ("wout", (h, v), False)]
    return spec


def head_spec(cfg: MoEConfig):
    """The head parameter group (final LN + output projection)."""
    h, v = cfg.d_model, cfg.vocab_size
    return [("lnf_scale", (h,), False), ("lnf_bias", (h,), False),
            ("wout", (h, v), False)]


def init_params(cfg: MoEConfig, seed: int = 0):
    """Initialize the flat param list (scaled-normal / zeros / ones)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape, _ in param_spec(cfg):
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base.endswith("_scale") or base.startswith("ln"):
            params.append(jnp.ones(shape, jnp.float32) if "scale" in base
                          else jnp.zeros(shape, jnp.float32))
        elif base.startswith("b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 0.02 if base in ("embed", "wout") else fan_in ** -0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def split_params(cfg: MoEConfig, params):
    """flat list -> (embed, [layer_param_lists], head_params)."""
    embed = params[0]
    layers = []
    off = 1
    for _ in range(cfg.n_layers):
        layers.append(params[off:off + N_LAYER_PARAMS])
        off += N_LAYER_PARAMS
    head = params[off:off + 3]
    return embed, layers, head


# ---------------------------------------------------------------------------
# Forward / loss.
# ---------------------------------------------------------------------------

def embed_fwd(tokens, embed):
    """[B,T] int32 -> [B,T,H] via take (lowered as gather)."""
    return jnp.take(embed, tokens, axis=0)


def embed_bwd(tokens, d_x, vocab_size: int):
    """Embedding gradient: one-hot^T @ d_x (scatter-add as MXU matmul)."""
    B, T, H = d_x.shape
    oh = jax.nn.one_hot(tokens.reshape(-1), vocab_size, dtype=jnp.float32)
    return oh.T @ d_x.reshape(B * T, H)


def head_fwd(cfg: MoEConfig, x, lnf_s, lnf_b, wout, labels):
    """Final LN + logits + mean CE loss. Returns scalar loss."""
    z = layer_norm(x, lnf_s, lnf_b)
    logits = z @ wout                                 # [B,T,V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def head_infer(cfg: MoEConfig, x, lnf_s, lnf_b, wout):
    """Greedy next token from the last position. Returns [B] int32."""
    z = layer_norm(x[:, -1, :], lnf_s, lnf_b)
    logits = z @ wout
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def forward(cfg: MoEConfig, params, tokens, labels):
    """Full forward. Returns (loss, ce_loss, aux_loss)."""
    embed, layers, (lnf_s, lnf_b, wout) = split_params(cfg, params)
    x = embed_fwd(tokens, embed)
    aux_total = 0.0
    for lp in layers:
        x, aux = decoder_layer(cfg, x, lp)
        aux_total = aux_total + aux
    ce = head_fwd(cfg, x, lnf_s, lnf_b, wout, labels)
    loss = ce + cfg.aux_loss_weight * aux_total
    return loss, ce, aux_total


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------

def adamw_flat(cfg: MoEConfig, p, g, m, v, step, lr):
    """Elementwise AdamW on a fused 1-D group (step: f32 >= 1)."""
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p, m, v


def train_step(cfg: MoEConfig, params, ms, vs, step, lr, tokens, labels):
    """Fused fwd+bwd+AdamW. Returns (params', ms', vs', loss, ce, aux)."""
    def loss_fn(ps):
        loss, ce, aux = forward(cfg, ps, tokens, labels)
        return loss, (ce, aux)

    (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, ms, vs):
        p2, m2, v2 = adamw_flat(cfg, p.reshape(-1), g.reshape(-1),
                                m.reshape(-1), v.reshape(-1), step, lr)
        new_p.append(p2.reshape(p.shape))
        new_m.append(m2.reshape(p.shape))
        new_v.append(v2.reshape(p.shape))
    return new_p, new_m, new_v, loss, ce, aux


# ---------------------------------------------------------------------------
# Per-layer entry points (offload training / ring-memory inference).
# ---------------------------------------------------------------------------

def layer_fwd(cfg: MoEConfig, x, layer_params):
    """Single decoder layer forward — contract v3 (the fused fast path).

    Returns (y [B,T,H], aux scalar, route_expert [B,T] i32,
    route_gate [B,T] f32, route_pos [B,T] i32, route_keep [B,T] f32,
    h [B,T,H], moe_in [B,T,H]): besides the per-token routing decisions
    (contract v2), the dense-prefix activations ride out as first-class
    outputs — `h` is the post-attention residual hidden, `moe_in` its
    ln2 normalization (the dispatch input). Together with the routing
    quadruple they are exactly the `expert_tail` input set, so a
    plan-miss repair re-executes ONLY the MoE block with the missed
    expert weights spliced in — no second attention pass. All emitted
    values depend only on the dense prefix, never on the staged expert
    weights.
    """
    return decoder_layer_split(cfg, x, layer_params)


def layer_dense(cfg: MoEConfig, x, dense_params):
    """The layer's dense half — contract v3's `layer_dense` artifact.

    Takes only the `N_DENSE_PARAMS` dense tensors. Returns
    (h, moe_in, aux, route_expert, route_gate, route_pos, route_keep).
    """
    return dense_prefix(cfg, x, dense_params)


def expert_tail(cfg: MoEConfig, h, moe_in, expert, gate, pos, keep,
                w1, b1, w2, b2):
    """The layer's sparse half — contract v3's `expert_tail` artifact.

    Activations + routing from `layer_dense`/`layer_fwd`, parameters =
    the expert tensors only. Returns y [B,T,H].
    """
    return _expert_tail(cfg, h, moe_in, expert, gate, pos, keep,
                        w1, b1, w2, b2)


def layer_bwd(cfg: MoEConfig, x, layer_params, dy, daux):
    """Single layer backward with recompute (per-layer checkpointing).

    Returns (dx, [dparams...]) — gradient w.r.t. input and each layer param.
    """
    def f(xx, lps):
        return decoder_layer(cfg, xx, lps)

    _, vjp = jax.vjp(f, x, list(layer_params))
    dx, dps = vjp((dy, daux))
    return dx, dps


def head_grad(cfg: MoEConfig, x, lnf_s, lnf_b, wout, labels):
    """Loss + gradients at the head. Returns (loss, dx, d_lnf_s, d_lnf_b, d_wout)."""
    def f(xx, a, b, w):
        return head_fwd(cfg, xx, a, b, w, labels)

    loss, (dx, da, db, dw) = jax.value_and_grad(f, argnums=(0, 1, 2, 3))(
        x, lnf_s, lnf_b, wout)
    return loss, dx, da, db, dw
