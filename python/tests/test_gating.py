"""Pallas top-1 gating kernel vs pure-jnp oracle (hypothesis sweeps)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref
from .conftest import assert_close


def _logits(seed, T, E):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=(T, E)) * 2.0, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(T=st.integers(2, 64), E=st.integers(2, 32),
       cap=st.integers(1, 16), seed=st.integers(0, 2**16))
def test_gating_matches_ref(T, E, cap, seed):
    logits = _logits(seed, T, E)
    outs_p = K.top1_gating_pallas(logits, cap)
    outs_r = ref.top1_gating_ref(logits, cap)
    for name, a, b in zip("expert gate pos keep me ce".split(), outs_p, outs_r):
        assert_close(a, b, msg=name)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(2, 32), E=st.integers(2, 8), seed=st.integers(0, 2**16))
def test_gating_capacity_invariants(T, E, seed):
    """No expert receives more than `cap` kept tokens; pos is a bijection."""
    cap = max(1, (2 * T) // E)
    logits = _logits(seed, T, E)
    expert, gate, pos, keep, me, ce = (np.asarray(o) for o in
                                       K.top1_gating_pallas(logits, cap))
    for e in range(E):
        kept = (expert == e) & (keep > 0.5)
        assert kept.sum() <= cap
        # Slots within an expert are unique and contiguous from 0.
        slots = np.sort(pos[kept])
        assert (slots == np.arange(len(slots))).all()
    # Dropped tokens contribute zero gate.
    assert (gate[keep < 0.5] == 0).all()
    # me/ce are probability-mass summaries.
    assert abs(me.sum() - 1.0) < 1e-5
    assert abs(ce.sum() - 1.0) < 1e-5


def test_gating_grad_matches_ref():
    """custom_vjp backward == jax.grad through the oracle."""
    T, E, cap = 24, 6, 8
    logits = _logits(7, T, E)
    _, _, pos, keep, _, _ = ref.top1_gating_ref(logits, cap)

    def f_pallas(lg):
        _, gate, _, _, me, _ = K.top1_gating(lg, cap)
        return jnp.sum(gate ** 2) + jnp.sum(me * jnp.arange(E))

    def f_ref(lg):
        _, gate, _, _, me, _ = ref.top1_gating_ref(lg, cap)
        return jnp.sum(gate ** 2) + jnp.sum(me * jnp.arange(E))

    assert_close(jax.grad(f_pallas)(logits), jax.grad(f_ref)(logits),
                 rtol=1e-4, atol=1e-5)


def test_aux_loss_uniform_routing_is_one():
    """Perfectly balanced routing gives aux loss == 1 (switch normalization)."""
    E = 8
    me = jnp.full((E,), 1.0 / E)
    ce = jnp.full((E,), 1.0 / E)
    assert abs(float(ref.aux_loss_ref(me, ce)) - 1.0) < 1e-6
