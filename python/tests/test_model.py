"""L2 model-level tests: shapes, training signal, per-layer == fused chain."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import get_config, PRESETS
from compile import model as M


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny")
    params = M.init_params(cfg, 0)
    r = np.random.default_rng(0)
    tok = jnp.asarray(r.integers(0, cfg.vocab_size, (cfg.batch_size, cfg.seq_len)), jnp.int32)
    lab = jnp.asarray(r.integers(0, cfg.vocab_size, (cfg.batch_size, cfg.seq_len)), jnp.int32)
    return cfg, params, tok, lab


def test_param_spec_counts_match_formula():
    for name, cfg in PRESETS.items():
        spec = M.param_spec(cfg)
        total = sum(int(np.prod(s)) if s else 1 for _, s, _ in spec)
        assert total == cfg.param_counts()["total"], name


def test_sparse_fraction_dominates_in_base():
    """The paper's premise: expert (sparse) params are the bulk of the model."""
    cfg = get_config("base")
    c = cfg.param_counts()
    sparse = c["per_layer_sparse"] * cfg.n_layers
    assert sparse / c["total"] > 0.9
    assert c["total"] > 90e6  # ~100M-class


def test_initial_loss_near_uniform(tiny):
    cfg, params, tok, lab = tiny
    loss, ce, aux = M.forward(cfg, params, tok, lab)
    assert abs(float(ce) - np.log(cfg.vocab_size)) < 0.5
    assert 0.5 < float(aux) < 4.0  # aux ~ 1 for balanced routing


def test_train_step_reduces_loss(tiny):
    cfg, params, tok, lab = tiny
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    step = jax.jit(lambda p, m, v, s: M.train_step(
        cfg, p, m, v, s, jnp.float32(1e-3), tok, lab))
    losses = []
    p, m, v = params, ms, vs
    for i in range(5):
        p, m, v, loss, ce, aux = step(p, m, v, jnp.float32(i + 1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1


def test_layer_chain_equals_fused_forward(tiny):
    """embed_fwd + layer_fwd* + head_fwd == forward (artifact-chain parity).

    This is the invariant the rust offload trainer relies on: running the
    per-layer artifacts in sequence must equal the fused fwd_loss artifact.
    """
    cfg, params, tok, lab = tiny
    embed, layers, (lnf_s, lnf_b, wout) = M.split_params(cfg, params)
    x = M.embed_fwd(tok, embed)
    aux_total = 0.0
    for lp in layers:
        x, aux, *_ = M.layer_fwd(cfg, x, lp)
        aux_total += aux
    ce = M.head_fwd(cfg, x, lnf_s, lnf_b, wout, lab)
    loss_chain = ce + cfg.aux_loss_weight * aux_total
    loss_fused, _, _ = M.forward(cfg, params, tok, lab)
    np.testing.assert_allclose(float(loss_chain), float(loss_fused), rtol=1e-5)


def test_layer_bwd_matches_autodiff(tiny):
    cfg, params, tok, lab = tiny
    embed, layers, _ = M.split_params(cfg, params)
    x = M.embed_fwd(tok, embed)
    r = np.random.default_rng(3)
    dy = jnp.asarray(r.normal(size=x.shape) * 0.1, jnp.float32)

    dx, dps = M.layer_bwd(cfg, x, layers[0], dy, jnp.float32(0.0))

    def f(xx, lps):
        y, aux, *_ = M.layer_fwd(cfg, xx, lps)
        return jnp.sum(y * dy)

    dx_ref, dps_ref = jax.grad(f, argnums=(0, 1))(x, list(layers[0]))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=2e-3, atol=1e-4)
    for a, b in zip(dps, dps_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-4)


def test_embed_bwd_is_scatter_add(tiny):
    cfg, params, tok, _ = tiny
    r = np.random.default_rng(5)
    dx = jnp.asarray(r.normal(size=(cfg.batch_size, cfg.seq_len, cfg.d_model)),
                     jnp.float32)
    d = np.asarray(M.embed_bwd(tok, dx, cfg.vocab_size))
    want = np.zeros((cfg.vocab_size, cfg.d_model), np.float32)
    tnp = np.asarray(tok)
    dnp = np.asarray(dx)
    for b in range(cfg.batch_size):
        for t in range(cfg.seq_len):
            want[tnp[b, t]] += dnp[b, t]
    np.testing.assert_allclose(d, want, rtol=1e-4, atol=1e-5)


def test_adamw_flat_step():
    cfg = get_config("tiny")
    p = jnp.ones((8,)) * 2.0
    g = jnp.ones((8,))
    m = jnp.zeros((8,))
    v = jnp.zeros((8,))
    p2, m2, v2 = M.adamw_flat(cfg, p, g, m, v, jnp.float32(1), jnp.float32(0.1))
    # bias-corrected first step: mhat=g, vhat=g^2 -> update ≈ lr*(1 + wd*p)
    want = 2.0 - 0.1 * (1.0 / (1.0 + cfg.eps) + cfg.weight_decay * 2.0)
    np.testing.assert_allclose(np.asarray(p2), want, rtol=1e-4)


def test_head_infer_greedy(tiny):
    cfg, params, tok, _ = tiny
    embed, layers, (lnf_s, lnf_b, wout) = M.split_params(cfg, params)
    x = M.embed_fwd(tok, embed)
    ids = M.head_infer(cfg, x, lnf_s, lnf_b, wout)
    assert ids.shape == (cfg.batch_size,)
    assert ids.dtype == jnp.int32
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < cfg.vocab_size).all()
