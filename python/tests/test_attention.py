"""Pallas fused causal MHA (fwd + bwd) vs oracle."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref
from .conftest import assert_close


def _qkv(seed, B, N, T, Dh):
    r = np.random.default_rng(seed)
    f = lambda: jnp.asarray(r.normal(size=(B, N, T, Dh)), jnp.float32)
    return f(), f(), f()


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), N=st.integers(1, 4),
       T=st.sampled_from([2, 8, 17, 32]), Dh=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**16))
def test_attention_matches_ref(B, N, T, Dh, seed):
    q, k, v = _qkv(seed, B, N, T, Dh)
    assert_close(K.attention_pallas(q, k, v), ref.attention_ref(q, k, v),
                 rtol=1e-4, atol=1e-5)


def test_attention_is_causal():
    """Future positions must not influence earlier outputs."""
    q, k, v = _qkv(0, 1, 2, 16, 8)
    o0 = np.asarray(K.attention_pallas(q, k, v))
    # Perturb the last timestep of k/v; outputs at t < 15 must be unchanged.
    k2 = k.at[:, :, -1].add(3.0)
    v2 = v.at[:, :, -1].add(3.0)
    o1 = np.asarray(K.attention_pallas(q, k2, v2))
    assert_close(o0[:, :, :-1], o1[:, :, :-1])
    assert not np.allclose(o0[:, :, -1], o1[:, :, -1])


def test_attention_first_token_is_v0():
    """Causal row 0 attends only to itself: out[0] == v[0]."""
    q, k, v = _qkv(1, 2, 2, 8, 4)
    o = np.asarray(K.attention_pallas(q, k, v))
    assert_close(o[:, :, 0], np.asarray(v)[:, :, 0], rtol=1e-5, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_attention_bwd_matches_autodiff_of_ref(seed):
    B, N, T, Dh = 2, 2, 12, 8
    q, k, v = _qkv(seed, B, N, T, Dh)
    do = jnp.asarray(np.random.default_rng(seed + 9).normal(size=(B, N, T, Dh)),
                     jnp.float32)

    def f(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) * do)

    g_ref = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g_ker = K.attention_bwd_pallas(q, k, v, do)
    for name, a, b in zip("dq dk dv".split(), g_ker, g_ref):
        assert_close(a, b, rtol=2e-3, atol=1e-4, msg=name)
