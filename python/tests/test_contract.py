"""Artifact-contract v2: `layer_fwd` emits the routing decisions.

This is the Python half of the contract the rust coordinator depends on
(`runtime/registry.rs::CONTRACT_VERSION`): output names, dtypes and
shapes of the v2 `layer_fwd` entry, plus the two semantic invariants the
route-repair path is built on —

  1. the emitted top-1 set equals a dense-prefix recompute (the shadow
     oracle's argmax), and
  2. the routing outputs do NOT depend on the expert weights, so they
     are valid even when stale expert tensors were staged (the engine
     repairs by splicing the missed experts and re-running the layer).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.aot import CONTRACT_VERSION, entry_layer_fwd
from compile.configs import get_config
from compile.layers import LAYER_PARAM_NAMES, layer_norm, mha_block


def _tiny():
    cfg = get_config("tiny")
    params = M.init_params(cfg, 0)
    _, layers, _ = M.split_params(cfg, params)
    r = np.random.default_rng(7)
    x = jnp.asarray(
        r.normal(size=(cfg.batch_size, cfg.seq_len, cfg.d_model)) * 0.5,
        jnp.float32)
    return cfg, layers[0], x


def test_contract_version_is_two():
    assert CONTRACT_VERSION == 2


def test_layer_fwd_entry_matches_documented_contract():
    """Names, order, dtypes and shapes of the v2 `layer_fwd` outputs."""
    cfg = get_config("tiny")
    _, ins, outs = entry_layer_fwd(cfg)
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model
    assert ins[0][0] == "x" and tuple(ins[0][1].shape) == (B, T, H)
    assert [n for n, _ in ins[1:]] == [n for n, _ in LAYER_PARAM_NAMES]
    got = [(n, tuple(s.shape), s.dtype) for n, s in outs]
    assert got == [
        ("y", (B, T, H), jnp.float32),
        ("aux", (), jnp.float32),
        ("route_expert", (B, T), jnp.int32),
        ("route_gate", (B, T), jnp.float32),
    ]


def test_layer_fwd_returns_routing_in_range():
    cfg, lp, x = _tiny()
    y, aux, expert, gate = M.layer_fwd(cfg, x, lp)
    assert y.shape == x.shape
    e = np.asarray(expert)
    g = np.asarray(gate)
    assert e.shape == (cfg.batch_size, cfg.seq_len)
    assert e.dtype == np.int32
    assert (e >= 0).all() and (e < cfg.n_experts).all()
    # gate = softmax prob of the chosen expert × keep ∈ [0, 1]; a top-1
    # softmax winner over E logits is always at least 1/E when kept.
    assert (g >= 0.0).all() and (g <= 1.0).all()
    kept = g > 0.0
    assert (g[kept] >= 1.0 / cfg.n_experts - 1e-6).all()


def test_emitted_routing_matches_dense_prefix_recompute():
    """Kernel-emitted set == the shadow oracle's argmax (parity)."""
    cfg, lp, x = _tiny()
    _, _, expert, _ = M.layer_fwd(cfg, x, lp)
    (ln1_s, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_s, ln2_b, rw, rb, *_rest) = lp
    a = mha_block(cfg, layer_norm(x, ln1_s, ln1_b),
                  wq, bq, wk, bk, wv, bv, wo, bo)
    logits = layer_norm(x + a, ln2_s, ln2_b) @ rw + rb
    want = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(expert),
                                  np.asarray(want))


def test_routing_outputs_ignore_expert_weights():
    """The repair-path invariant: staging stale (here: zeroed) expert
    weights changes `y` but NOT `route_expert`/`route_gate`."""
    cfg, lp, x = _tiny()
    y, _, expert, gate = M.layer_fwd(cfg, x, lp)
    stale = list(lp)
    names = [n for n, _ in LAYER_PARAM_NAMES]
    for n in ("w1", "b1", "w2", "b2"):
        i = names.index(n)
        stale[i] = jnp.zeros_like(stale[i])
    y2, _, expert2, gate2 = M.layer_fwd(cfg, x, stale)
    np.testing.assert_array_equal(np.asarray(expert), np.asarray(expert2))
    np.testing.assert_array_equal(np.asarray(gate), np.asarray(gate2))
    assert not np.allclose(np.asarray(y), np.asarray(y2)), \
        "expert weights must matter for y (sanity)"
