"""Artifact-contract v3: the layer splits at the dense/sparse boundary.

This is the Python half of the contract the rust coordinator depends on
(`runtime/registry.rs::CONTRACT_VERSION`): output names, dtypes and
shapes of the v3 `layer_fwd` / `layer_dense` / `expert_tail` entries,
plus the semantic invariants the tail-only repair path is built on —

  1. `layer_dense ∘ expert_tail` is BIT-IDENTICAL to the fused
     `layer_fwd`, across routing patterns (balanced, skewed,
     capacity-dropping),
  2. the routing quadruple and the dense-prefix activations
     (`h`, `moe_in`) do NOT depend on the expert weights, so they are
     valid even when stale expert tensors were staged, and
  3. feeding `expert_tail` the activations a stale-weight `layer_fwd`
     emitted, with the TRUE expert weights spliced in, reproduces the
     true fused output bit for bit — the contract-v3 repair: no second
     attention pass, ever.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import (CONTRACT_VERSION, entry_expert_tail,
                         entry_layer_dense, entry_layer_fwd)
from compile.configs import get_config
from compile.layers import (LAYER_PARAM_NAMES, N_DENSE_PARAMS, layer_norm,
                            mha_block)


def _tiny(seed=7, scale=0.5):
    cfg = get_config("tiny")
    params = M.init_params(cfg, 0)
    _, layers, _ = M.split_params(cfg, params)
    r = np.random.default_rng(seed)
    x = jnp.asarray(
        r.normal(size=(cfg.batch_size, cfg.seq_len, cfg.d_model)) * scale,
        jnp.float32)
    return cfg, layers[0], x


def test_contract_version_is_three():
    assert CONTRACT_VERSION == 3


def test_layer_fwd_entry_matches_documented_contract():
    """Names, order, dtypes and shapes of the v3 `layer_fwd` outputs."""
    cfg = get_config("tiny")
    _, ins, outs = entry_layer_fwd(cfg)
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model
    assert ins[0][0] == "x" and tuple(ins[0][1].shape) == (B, T, H)
    assert [n for n, _ in ins[1:]] == [n for n, _ in LAYER_PARAM_NAMES]
    got = [(n, tuple(s.shape), s.dtype) for n, s in outs]
    assert got == [
        ("y", (B, T, H), jnp.float32),
        ("aux", (), jnp.float32),
        ("route_expert", (B, T), jnp.int32),
        ("route_gate", (B, T), jnp.float32),
        ("route_pos", (B, T), jnp.int32),
        ("route_keep", (B, T), jnp.float32),
        ("h", (B, T, H), jnp.float32),
        ("moe_in", (B, T, H), jnp.float32),
    ]


def test_split_entries_match_documented_contract():
    """`layer_dense` takes only dense params; `expert_tail` only expert
    params + the dense activations/routing — the split the repair paths
    rely on."""
    cfg = get_config("tiny")
    B, T, H = cfg.batch_size, cfg.seq_len, cfg.d_model

    _, d_ins, d_outs = entry_layer_dense(cfg)
    dense_names = [n for n, sp in LAYER_PARAM_NAMES if not sp]
    assert [n for n, _ in d_ins] == ["x"] + dense_names
    assert [(n, tuple(s.shape), s.dtype) for n, s in d_outs] == [
        ("h", (B, T, H), jnp.float32),
        ("moe_in", (B, T, H), jnp.float32),
        ("aux", (), jnp.float32),
        ("route_expert", (B, T), jnp.int32),
        ("route_gate", (B, T), jnp.float32),
        ("route_pos", (B, T), jnp.int32),
        ("route_keep", (B, T), jnp.float32),
    ]

    _, t_ins, t_outs = entry_expert_tail(cfg)
    sparse_names = [n for n, sp in LAYER_PARAM_NAMES if sp]
    assert [n for n, _ in t_ins] == (
        ["h", "moe_in", "route_expert", "route_gate", "route_pos",
         "route_keep"] + sparse_names)
    assert sparse_names == ["w1", "b1", "w2", "b2"]
    assert [(n, tuple(s.shape)) for n, s in t_outs] == [("y", (B, T, H))]


@pytest.mark.parametrize("seed,scale", [(7, 0.5), (11, 0.05), (23, 4.0)])
def test_dense_tail_composition_is_bit_identical_to_fused(seed, scale):
    """The tentpole invariant: layer_dense ∘ expert_tail ≡ layer_fwd,
    bitwise, across routing patterns (the large-scale input drives
    skewed routing and capacity drops)."""
    cfg, lp, x = _tiny(seed, scale)
    fused = M.layer_fwd(cfg, x, lp)
    h, moe_in, aux, e, g, p, k = M.layer_dense(cfg, x, lp[:N_DENSE_PARAMS])
    y = M.expert_tail(cfg, h, moe_in, e, g, p, k, *lp[N_DENSE_PARAMS:])
    for name, a, b in [
        ("y", fused[0], y), ("aux", fused[1], aux),
        ("route_expert", fused[2], e), ("route_gate", fused[3], g),
        ("route_pos", fused[4], p), ("route_keep", fused[5], k),
        ("h", fused[6], h), ("moe_in", fused[7], moe_in),
    ]:
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{name} must be bit-identical between fused and split")


def test_layer_fwd_returns_routing_in_range():
    cfg, lp, x = _tiny()
    y, aux, expert, gate, pos, keep, h, moe_in = M.layer_fwd(cfg, x, lp)
    assert y.shape == x.shape and h.shape == x.shape and moe_in.shape == x.shape
    e = np.asarray(expert)
    g = np.asarray(gate)
    p = np.asarray(pos)
    k = np.asarray(keep)
    assert e.shape == (cfg.batch_size, cfg.seq_len)
    assert e.dtype == np.int32 and p.dtype == np.int32
    assert (e >= 0).all() and (e < cfg.n_experts).all()
    assert ((k == 0.0) | (k == 1.0)).all()
    # kept tokens sit inside their expert's capacity buffer
    assert (p[k == 1.0] < cfg.expert_capacity).all() and (p >= 0).all()
    # gate = softmax prob of the chosen expert × keep ∈ [0, 1]; a top-1
    # softmax winner over E logits is always at least 1/E when kept.
    assert (g >= 0.0).all() and (g <= 1.0).all()
    kept = g > 0.0
    assert (g[kept] >= 1.0 / cfg.n_experts - 1e-6).all()


def test_emitted_routing_matches_dense_prefix_recompute():
    """Kernel-emitted set == the shadow oracle's argmax (parity)."""
    cfg, lp, x = _tiny()
    _, _, expert, *_ = M.layer_fwd(cfg, x, lp)
    (ln1_s, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_s, ln2_b, rw, rb, *_rest) = lp
    a = mha_block(cfg, layer_norm(x, ln1_s, ln1_b),
                  wq, bq, wk, bk, wv, bv, wo, bo)
    logits = layer_norm(x + a, ln2_s, ln2_b) @ rw + rb
    want = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(expert),
                                  np.asarray(want))


def test_routing_and_activations_ignore_expert_weights():
    """The repair-path invariant: staging stale (here: zeroed) expert
    weights changes `y` but NOT the routing quadruple or the
    dense-prefix activations."""
    cfg, lp, x = _tiny()
    true_out = M.layer_fwd(cfg, x, lp)
    stale = list(lp)
    names = [n for n, _ in LAYER_PARAM_NAMES]
    for n in ("w1", "b1", "w2", "b2"):
        i = names.index(n)
        stale[i] = jnp.zeros_like(stale[i])
    stale_out = M.layer_fwd(cfg, x, stale)
    for i, name in enumerate(["route_expert", "route_gate", "route_pos",
                              "route_keep", "h", "moe_in"], start=2):
        np.testing.assert_array_equal(
            np.asarray(true_out[i]), np.asarray(stale_out[i]),
            err_msg=f"{name} must not depend on expert weights")
    assert not np.allclose(np.asarray(true_out[0]), np.asarray(stale_out[0])), \
        "expert weights must matter for y (sanity)"


def test_tail_rerun_repairs_a_stale_forward_bitwise():
    """The contract-v3 repair, end to end: a fused forward ran with
    stale expert weights; `expert_tail` on its emitted activations with
    the TRUE expert weights reproduces the true fused `y` bit for bit —
    the dense prefix (attention included) is never recomputed."""
    cfg, lp, x = _tiny(seed=5)
    stale = list(lp)
    for i in range(N_DENSE_PARAMS, len(lp)):
        stale[i] = jnp.zeros_like(stale[i])
    stale_out = M.layer_fwd(cfg, x, stale)
    true_out = M.layer_fwd(cfg, x, lp)
    y_rep = M.expert_tail(
        cfg, stale_out[6], stale_out[7], stale_out[2], stale_out[3],
        stale_out[4], stale_out[5], *lp[N_DENSE_PARAMS:])
    np.testing.assert_array_equal(
        np.asarray(y_rep), np.asarray(true_out[0]),
        err_msg="tail re-execution must equal the full-layer re-run")


def test_tail_ignores_unrouted_expert_weights():
    """Zero-inertness at tail granularity: corrupting an expert NO token
    routes to leaves the tail output bit-identical (the basis for
    splicing only missed experts), while corrupting a routed one flips
    it (sensitivity)."""
    cfg, lp, x = _tiny(seed=9)
    # Force an unrouted expert: a large negative router bias keeps the
    # argmax away from expert 0 whatever the tokens are.
    names = [n for n, _ in LAYER_PARAM_NAMES]
    rb_idx = names.index("router_b")
    lp = list(lp)
    lp[rb_idx] = lp[rb_idx].at[0].set(-1e9)
    out = M.layer_fwd(cfg, x, lp)
    e_ids = np.asarray(out[2]).reshape(-1)
    routed = set(int(v) for v in e_ids)
    unrouted = [e for e in range(cfg.n_experts) if e not in routed]
    assert 0 in unrouted, "biased-out expert must be unrouted"
    tail = list(lp[N_DENSE_PARAMS:])
    w1_idx = names[N_DENSE_PARAMS:].index("w1")
    corrupt = tail[w1_idx].at[unrouted[0]].set(1e6)
    tail_c = list(tail)
    tail_c[w1_idx] = corrupt
    y_c = M.expert_tail(cfg, out[6], out[7], out[2], out[3], out[4], out[5],
                        *tail_c)
    np.testing.assert_array_equal(np.asarray(y_c), np.asarray(out[0]))
    r = next(iter(routed))
    tail_r = list(tail)
    tail_r[w1_idx] = tail[w1_idx].at[r].set(1e6)
    y_r = M.expert_tail(cfg, out[6], out[7], out[2], out[3], out[4], out[5],
                        *tail_r)
    assert not np.array_equal(np.asarray(y_r), np.asarray(out[0])), \
        "a routed expert's weights must matter (sanity)"
