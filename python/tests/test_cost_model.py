"""Python mirror of ``sim::CostModel``'s dispatch-lane arithmetic.

Pure-python re-derivation (no jax needed) of the byte formulas behind
the adaptive dispatch planner (rust/src/sim/cost_model.rs,
docs/distributed.md §Token dispatch):

- weight lane:  E[routed experts] x remote fraction x fused block bytes
- token lane:   2 x kept_tokens x d_model x 4 (rows out + results back)
- crossover:    tokens win iff the token bill is strictly smaller
- fabric:       hierarchical AllToAll never slower than flat on the
                Figure-7 link model

Constants mirror ``local_preset("deep")`` and the default
``ClusterConfig`` — if either side drifts, this file or the rust tests
fail, not both.
"""

import math

# local_preset("deep") — config/presets.rs
D_MODEL = 128
D_FF = 512
N_EXPERTS = 8
N_LAYERS = 12

# Default ClusterConfig link model — config/cluster.rs
# (bandwidth bytes/s, latency s)
LINKS = {
    "nvlink": (300e9, 2e-6),
    "tor": (25e9, 5e-6),
    "leaf": (20e9, 10e-6),
    "spine": (16e9, 20e-6),
}


def expert_block_bytes(h=D_MODEL, f=D_FF):
    """Fused expert FFN block: w_in (h,f) + b_in (f) + w_out (f,h) + b_out (h), f32."""
    return (2 * h * f + f + h) * 4


def expected_routed_experts(tokens, zipf_s, e=N_EXPERTS):
    """E[distinct experts] = sum_e 1 - (1 - w_e/Z)^T, w_e = 1/(e+1)^s."""
    w = [1.0 / (i + 1) ** zipf_s for i in range(e)]
    z = sum(w)
    return sum(1.0 - (1.0 - wi / z) ** tokens for wi in w)


def token_dispatch_layer_bytes(tokens, h=D_MODEL):
    return 2.0 * tokens * h * 4.0


def dist_token_a2a_bytes(tokens, world):
    if world <= 1:
        return 0.0
    return N_LAYERS * token_dispatch_layer_bytes(tokens)


def weight_dispatch_layer_bytes(tokens, zipf_s, world):
    if world <= 1:
        return 0.0
    routed = expected_routed_experts(tokens, zipf_s)
    remote_frac = (world - 1) / world
    return routed * remote_frac * expert_block_bytes()


def dist_a2a_bytes(tokens, zipf_s, world):
    return N_LAYERS * weight_dispatch_layer_bytes(tokens, zipf_s, world)


def choose_dispatch(weight_bytes, token_bytes):
    """dist::choose_dispatch — tokens iff strictly cheaper, ties to weights."""
    return "tokens" if token_bytes < weight_bytes else "weights"


# --------------------------------------------------------------- fabric

def _time_for(link, bytes_):
    if bytes_ <= 0.0:
        return 0.0
    bw, lat = LINKS[link]
    return lat + bytes_ / bw


def a2a_time(bytes_per_pair, strategy, p, n_nodes):
    """AllToAllPlan::price on a single-cluster fabric (frac_cross_cluster=0)."""
    b = bytes_per_pair
    if strategy == "flat":
        nvlink = (p - 1) * b
        same_rail = (n_nodes - 1) * b
        cross_rail = (n_nodes - 1) * (p - 1) * b
        tor = same_rail + cross_rail
        leaf = cross_rail  # + same_rail * frac_cross_cluster (= 0 here)
        spine = cross_rail
        return max(_time_for("nvlink", nvlink), _time_for("tor", tor),
                   _time_for("leaf", leaf), _time_for("spine", spine))
    nvlink = (p - 1) * n_nodes * b
    rail = (n_nodes - 1) * p * b
    return _time_for("nvlink", nvlink) + max(_time_for("tor", rail),
                                             _time_for("leaf", 0.0))


def dist_token_pass_secs(tokens, world, strategy, p, n_nodes):
    total = dist_token_a2a_bytes(tokens, world)
    if total <= 0.0:
        return 0.0
    pairs = world * (world - 1)
    return a2a_time(total / pairs, strategy, p, n_nodes)


# ---------------------------------------------------------------- tests

def test_token_layer_bytes_formula_and_linearity():
    assert token_dispatch_layer_bytes(1) == 2 * D_MODEL * 4
    assert token_dispatch_layer_bytes(128) == 128 * token_dispatch_layer_bytes(1)
    assert token_dispatch_layer_bytes(0) == 0.0


def test_token_a2a_bytes_ignore_world_size_above_one():
    # Payload rides one AllToAll regardless of fan-out: world only
    # changes who owns what, not how many rows travel.
    assert dist_token_a2a_bytes(64, 1) == 0.0
    assert dist_token_a2a_bytes(64, 2) == dist_token_a2a_bytes(64, 8)
    assert dist_token_a2a_bytes(64, 2) == N_LAYERS * 2 * 64 * D_MODEL * 4


def test_expected_routed_experts_bounds_and_skew():
    assert abs(expected_routed_experts(1, 0.0) - 1.0) < 1e-9
    assert expected_routed_experts(1e6, 0.0) > N_EXPERTS - 1e-3
    uni = expected_routed_experts(256, 0.0)
    z12 = expected_routed_experts(256, 1.2)
    z20 = expected_routed_experts(256, 2.0)
    assert uni > z12 > z20 >= 1.0


def test_crossover_tracks_batch_vs_block_size():
    # Mirrors token_dispatch_crossover_tracks_batch_vs_block_size in
    # rust/src/sim/cost_model.rs: deep preset blocks are ~527 KB, so a
    # handful of kept rows beats shipping even one block, while a flood
    # of rows loses to at most E blocks per layer.
    world = 2
    trickle, flood = 8, 65536
    assert choose_dispatch(
        weight_dispatch_layer_bytes(trickle, 0.0, world),
        token_dispatch_layer_bytes(trickle),
    ) == "tokens"
    assert choose_dispatch(
        weight_dispatch_layer_bytes(flood, 0.0, world),
        token_dispatch_layer_bytes(flood),
    ) == "weights"
    # Exact threshold: tokens win iff kept < routed_remote*block/(8*H).
    for s in (0.0, 1.2):
        for tokens in (4, 64, 1024, 16384):
            wb = weight_dispatch_layer_bytes(tokens, s, world)
            tb = token_dispatch_layer_bytes(tokens)
            threshold = wb / (8.0 * D_MODEL)
            assert (choose_dispatch(wb, tb) == "tokens") == (tokens < threshold)
    # Ties go to weights (dist::choose_dispatch).
    assert choose_dispatch(1.0, 1.0) == "weights"


def test_monster_blocks_always_favor_tokens():
    # table1-scale experts (d_model 4096, d_ff 16384 -> ~537 MB blocks):
    # no realistic batch reaches the crossover, which is why the rust
    # crossover test runs on the deep preset instead.
    block = expert_block_bytes(h=4096, f=16384)
    tokens = 4096 * 64  # a very large kept batch
    routed_remote = expected_routed_experts(tokens, 0.0, e=64) * 0.5  # world 2
    weight_bill = routed_remote * block
    assert token_dispatch_layer_bytes(tokens, h=4096) < weight_bill
    # The crossover batch (~routed_remote * block / (8H)) sits beyond
    # half a million kept rows — far past any preset's B*T.
    assert weight_bill / (8.0 * 4096) > 5e5


def test_hierarchical_never_slower_than_flat():
    # Single node (cluster_for_gpus(8)): both schedules are pure NVLink
    # and price identically; multi-node (4x8): flat pays the spine,
    # hierarchical stays rail-aligned and wins outright at MB scale.
    for b in (4096.0, 1e6):
        assert a2a_time(b, "hier", p=8, n_nodes=1) <= a2a_time(b, "flat", p=8, n_nodes=1) + 1e-12
    assert a2a_time(1e6, "hier", p=8, n_nodes=4) < a2a_time(1e6, "flat", p=8, n_nodes=4)
    # And through the pass-level wrapper (world = the fabric's 32 GPUs).
    hier = dist_token_pass_secs(4096, 32, "hier", p=8, n_nodes=4)
    flat = dist_token_pass_secs(4096, 32, "flat", p=8, n_nodes=4)
    assert 0.0 < hier <= flat
    assert dist_token_pass_secs(4096, 1, "flat", p=8, n_nodes=1) == 0.0
