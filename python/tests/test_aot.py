"""AOT artifact integrity: manifests complete, HLO text parseable-ish."""

import json
import os

import pytest

from compile.configs import get_config
from compile.aot import CONTRACT_VERSION, PRESET_ENTRIES

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest(preset):
    p = os.path.join(ART, preset, "manifest.json")
    if not os.path.exists(p):
        pytest.skip(f"artifacts for {preset} not built (run `make artifacts`)")
    with open(p) as f:
        return json.load(f)


@pytest.mark.parametrize("preset", list(PRESET_ENTRIES))
def test_manifest_covers_all_entries(preset):
    man = _manifest(preset)
    for entry in PRESET_ENTRIES[preset]:
        assert entry in man["artifacts"], entry
        f = os.path.join(ART, preset, man["artifacts"][entry]["file"])
        assert os.path.exists(f)
        text = open(f).read()
        assert text.startswith("HloModule"), f"{entry} not HLO text"
        assert "ENTRY" in text


@pytest.mark.parametrize("preset", list(PRESET_ENTRIES))
def test_manifest_param_layout_matches_config(preset):
    man = _manifest(preset)
    cfg = get_config(preset)
    total = sum(p["numel"] for p in man["params"])
    assert total == cfg.param_counts()["total"]
    sparse = sum(p["numel"] for p in man["params"] if p["sparse"])
    assert sparse == cfg.param_counts()["per_layer_sparse"] * cfg.n_layers


def test_train_step_io_arity():
    man = _manifest("tiny")
    cfg = get_config("tiny")
    P = len(man["params"])
    art = man["artifacts"]["train_step"]
    assert len(art["inputs"]) == 3 * P + 4
    assert len(art["outputs"]) == 3 * P + 3
    # tokens/labels are int32 with [B, T] shape
    tok = [i for i in art["inputs"] if i["name"] == "tokens"][0]
    assert tok["dtype"] == "i32"
    assert tok["shape"] == [cfg.batch_size, cfg.seq_len]


@pytest.mark.parametrize("preset", list(PRESET_ENTRIES))
def test_manifest_declares_current_contract(preset):
    """Every built manifest must be stamped with the contract version the
    rust coordinator checks (stale manifests are rejected with a
    "rebuild artifacts" error, never a shape panic)."""
    man = _manifest(preset)
    assert man.get("contract_version") == CONTRACT_VERSION


def test_layer_fwd_manifest_outputs_are_contract_v3():
    """Built layer_fwd artifacts must list the routed outputs AND the
    dense-prefix activations by name."""
    man = _manifest("deep")
    cfg = get_config("deep")
    outs = {o["name"]: o for o in man["artifacts"]["layer_fwd"]["outputs"]}
    assert set(outs) == {"y", "aux", "route_expert", "route_gate",
                         "route_pos", "route_keep", "h", "moe_in"}
    bt = [cfg.batch_size, cfg.seq_len]
    bth = bt + [cfg.d_model]
    assert outs["route_expert"]["dtype"] == "i32"
    assert outs["route_expert"]["shape"] == bt
    assert outs["route_pos"]["dtype"] == "i32"
    assert outs["route_gate"]["dtype"] == "f32"
    assert outs["route_keep"]["shape"] == bt
    assert outs["h"]["shape"] == bth and outs["moe_in"]["shape"] == bth


def test_split_layer_manifest_signatures_are_contract_v3():
    """The layer_dense/expert_tail pair must be present with the split
    signatures the tail-only repair paths address by name."""
    man = _manifest("deep")
    cfg = get_config("deep")
    bth = [cfg.batch_size, cfg.seq_len, cfg.d_model]
    dense = man["artifacts"]["layer_dense"]
    # only dense params in the signature: x + 14 tensors, no w1/b1/w2/b2
    in_names = [i["name"] for i in dense["inputs"]]
    assert in_names[0] == "x" and len(in_names) == 15
    assert not any(n in in_names for n in ("w1", "b1", "w2", "b2"))
    tail = man["artifacts"]["expert_tail"]
    t_in = [i["name"] for i in tail["inputs"]]
    assert t_in == ["h", "moe_in", "route_expert", "route_gate",
                    "route_pos", "route_keep", "w1", "b1", "w2", "b2"]
    t_out = {o["name"]: o for o in tail["outputs"]}
    assert list(t_out) == ["y"] and t_out["y"]["shape"] == bth


def test_layer_artifacts_share_shapes_across_layers():
    """Ring-memory inference reuses ONE layer executable for all layers."""
    man = _manifest("deep")
    art = man["artifacts"]["layer_fwd"]
    names = [i["name"] for i in art["inputs"]]
    assert names[0] == "x"
    # all inputs fixed-shape, layer-index-free
    assert not any("layer" in n for n in names)
