"""Pallas grouped expert FFN (fwd + bwd kernels) vs oracle."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref
from .conftest import assert_close


def _mk(seed, E, C, H, F):
    r = np.random.default_rng(seed)
    f = lambda *s: jnp.asarray(r.normal(size=s) * 0.1, jnp.float32)
    return f(E, C, H), f(E, H, F), f(E, F), f(E, F, H), f(E, H)


@settings(max_examples=20, deadline=None)
@given(E=st.integers(1, 8), C=st.integers(1, 16),
       H=st.sampled_from([8, 16, 32, 64]), F=st.sampled_from([16, 32, 128]),
       seed=st.integers(0, 2**16))
def test_ffn_fwd_matches_ref(E, C, H, F, seed):
    x, w1, b1, w2, b2 = _mk(seed, E, C, H, F)
    assert_close(K.expert_ffn_pallas(x, w1, b1, w2, b2),
                 ref.expert_ffn_ref(x, w1, b1, w2, b2), rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(E=st.integers(1, 4), C=st.integers(2, 8), seed=st.integers(0, 2**16))
def test_ffn_bwd_matches_autodiff_of_ref(E, C, seed):
    H, F = 16, 32
    x, w1, b1, w2, b2 = _mk(seed, E, C, H, F)
    dy = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(E, C, H)),
                     jnp.float32)

    def f_ref(x, w1, b1, w2, b2):
        return jnp.sum(ref.expert_ffn_ref(x, w1, b1, w2, b2) * dy)

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(x, w1, b1, w2, b2)
    g_ker = K.expert_ffn_bwd_pallas(x, w1, b1, w2, dy)
    for name, a, b in zip("dx dw1 db1 dw2 db2".split(), g_ker, g_ref):
        assert_close(a, b, rtol=2e-3, atol=1e-4, msg=name)


def test_ffn_expert_isolation():
    """Each expert's output depends only on its own slots and weights."""
    E, C, H, F = 4, 8, 16, 32
    x, w1, b1, w2, b2 = _mk(3, E, C, H, F)
    y0 = K.expert_ffn_pallas(x, w1, b1, w2, b2)
    # Perturb expert 2's input; experts 0,1,3 outputs must not move.
    x2 = x.at[2].add(1.0)
    y1 = K.expert_ffn_pallas(x2, w1, b1, w2, b2)
    for e in (0, 1, 3):
        assert_close(y0[e], y1[e])
    assert not np.allclose(np.asarray(y0[2]), np.asarray(y1[2]))


def test_ffn_zero_slots_stay_zero_bias_free():
    """Empty (zero-padded) capacity slots produce only the bias response."""
    E, C, H, F = 2, 4, 8, 16
    _, w1, b1, w2, b2 = _mk(5, E, C, H, F)
    x = jnp.zeros((E, C, H), jnp.float32)
    y = K.expert_ffn_pallas(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    assert_close(y, want)
    # All capacity rows identical (same bias path).
    assert_close(y[:, 0], y[:, -1])
