"""Shared pytest fixtures/helpers for kernel-vs-oracle comparisons."""

import numpy as np
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def randf(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def assert_close(a, b, rtol=1e-4, atol=1e-5, msg=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol, err_msg=msg)
