"""Pallas dispatch/combine kernels vs oracle + routing round-trip laws."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref
from .conftest import assert_close


def _routing(seed, T, E, cap):
    r = np.random.default_rng(seed)
    logits = jnp.asarray(r.normal(size=(T, E)) * 2, jnp.float32)
    return ref.top1_gating_ref(logits, cap), \
        jnp.asarray(r.normal(size=(T, 16)), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(T=st.integers(2, 48), E=st.integers(2, 8), cap=st.integers(1, 12),
       seed=st.integers(0, 2**16))
def test_dispatch_combine_match_ref(T, E, cap, seed):
    (expert, gate, pos, keep, _, _), x = _routing(seed, T, E, cap)
    buf_p = K.dispatch_pallas(x, expert, pos, keep, E, cap)
    buf_r = ref.dispatch_ref(x, expert, pos, keep, E, cap)
    assert_close(buf_p, buf_r)
    y_p = K.combine_pallas(buf_p, expert, pos, keep, gate)
    y_r = ref.combine_ref(buf_r, expert, pos, keep, gate)
    assert_close(y_p, y_r)


@settings(max_examples=15, deadline=None)
@given(T=st.integers(2, 32), E=st.integers(2, 8), seed=st.integers(0, 2**16))
def test_roundtrip_identity_for_kept_tokens(T, E, seed):
    """combine(dispatch(x)) with unit gates == x for kept tokens, 0 for dropped."""
    cap = T  # no drops possible
    (expert, gate, pos, keep, _, _), x = _routing(seed, T, E, cap)
    buf = K.dispatch_pallas(x, expert, pos, keep, E, cap)
    ones = jnp.ones_like(gate)
    y = K.combine_pallas(buf, expert, pos, keep, ones)
    assert_close(y, x, rtol=1e-5, atol=1e-6)


def test_dropped_tokens_vanish():
    T, E, cap = 16, 2, 2  # tiny capacity → drops guaranteed
    (expert, gate, pos, keep, _, _), x = _routing(11, T, E, cap)
    assert float(np.asarray(keep).sum()) < T
    buf = K.dispatch_pallas(x, expert, pos, keep, E, cap)
    y = K.combine_pallas(buf, expert, pos, keep, jnp.ones_like(gate))
    dropped = np.asarray(keep) < 0.5
    assert (np.abs(np.asarray(y)[dropped]) < 1e-6).all()


def test_dispatch_transpose_is_vjp():
    """dispatch_transpose == the linear-map transpose of dispatch."""
    T, E, cap, H = 12, 3, 4, 8
    r = np.random.default_rng(2)
    logits = jnp.asarray(r.normal(size=(T, E)), jnp.float32)
    expert, gate, pos, keep, _, _ = ref.top1_gating_ref(logits, cap)
    x = jnp.asarray(r.normal(size=(T, H)), jnp.float32)
    dbuf = jnp.asarray(r.normal(size=(E, cap, H)), jnp.float32)
    # <dispatch(x), dbuf> == <x, dispatch^T(dbuf)>
    lhs = jnp.sum(K.dispatch_pallas(x, expert, pos, keep, E, cap) * dbuf)
    rhs = jnp.sum(x * K.dispatch_transpose_pallas(dbuf, expert, pos, keep))
    assert abs(float(lhs) - float(rhs)) < 1e-3


def test_combine_gate_gradient():
    """d/dgate through custom_vjp matches autodiff of the oracle."""
    T, E, cap, H = 10, 3, 4, 8
    r = np.random.default_rng(4)
    logits = jnp.asarray(r.normal(size=(T, E)), jnp.float32)
    expert, gate, pos, keep, _, _ = ref.top1_gating_ref(logits, cap)
    y_buf = jnp.asarray(r.normal(size=(E, cap, H)), jnp.float32)

    f_k = lambda g: jnp.sum(K.combine(y_buf, expert, pos, keep, g) ** 2)
    f_r = lambda g: jnp.sum(ref.combine_ref(y_buf, expert, pos, keep, g) ** 2)
    assert_close(jax.grad(f_k)(gate), jax.grad(f_r)(gate), rtol=1e-4, atol=1e-5)
