//! Table 4 — embedding partition in data parallelism: per-rank memory
//! and comm volume vs the replicated-table AllReduce baseline, at the
//! paper's vocab (50304) and hidden sweeps (2048/4096/8192), plus a REAL
//! mesh execution at reduced scale verifying numerics and measuring
//! actual exchanged bytes. `cargo bench --bench table4_embedding_partition`.

use semoe::comm::Mesh;
use semoe::config::presets::table4_rows;
use semoe::metrics::Report;
use semoe::train::embedding_partition::{comm_bytes, EmbeddingShard};
use semoe::util::{human_bytes, Rng};

fn paper_rows(rep: &mut Report) {
    let vocab = 50304usize;
    let world = 8usize;
    let tokens_per_rank = 8 * 1024; // batch 8 × seq 1024
    let t = rep.table(
        "paper sweep (V=50304, 8 ranks)",
        &["hidden", "table GB (repl)", "shard GB (part)", "mem save",
          "allreduce MB/step", "3×a2a MB/step", "comm save",
          "paper mem save", "paper speedup"],
    );
    for row in table4_rows() {
        let h = row.hidden;
        let table_bytes = (vocab * h * 4) as u64;
        let shard_bytes = table_bytes / world as u64;
        let (full, part) = comm_bytes(vocab, h, tokens_per_rank, world);
        rep.row(
            t,
            vec![
                h.to_string(),
                format!("{:.2}", table_bytes as f64 / 1e9),
                format!("{:.2}", shard_bytes as f64 / 1e9),
                format!("{:.0}%", (1.0 - 1.0 / world as f64) * 100.0),
                format!("{:.1}", full as f64 / 1e6),
                format!("{:.1}", part as f64 / 1e6),
                format!("{:.0}%", (1.0 - part as f64 / full as f64) * 100.0),
                format!(
                    "{:.1}%",
                    (1.0 - row.paper_partition_mem_gb / row.paper_baseline_mem_gb) * 100.0
                ),
                format!(
                    "{:.1}%",
                    (row.paper_partition_tps / row.paper_baseline_tps - 1.0) * 100.0
                ),
            ],
        );
    }
    rep.note("paper memory saving is of WHOLE-rank memory (embedding is one slice of it); \
              our mem-save column is of the embedding table itself");
}

fn real_mesh(rep: &mut Report) {
    let (vocab, h, world, tokens) = (4096usize, 256usize, 4usize, 512usize);
    let mut rng = Rng::new(1);
    let table: Vec<f32> = (0..vocab * h).map(|_| rng.normal() as f32).collect();
    let handles = Mesh::new(world);
    let joins: Vec<_> = handles
        .into_iter()
        .map(|mut m| {
            let table = table.clone();
            std::thread::spawn(move || {
                let shard = EmbeddingShard::new(m.rank(), world, vocab, h, &table);
                let mut rng = Rng::new(50 + m.rank() as u64);
                let toks: Vec<usize> = (0..tokens).map(|_| rng.below(vocab)).collect();
                let t0 = std::time::Instant::now();
                let fwd = shard.forward(&mut m, &toks);
                let d_out = vec![1.0f32; toks.len() * h];
                let _grad = shard.backward(&mut m, &toks, &d_out);
                let wall = t0.elapsed().as_secs_f64();
                // verify against the full table
                for (i, &tk) in toks.iter().enumerate() {
                    assert_eq!(&fwd[i * h..(i + 1) * h], &table[tk * h..(tk + 1) * h]);
                }
                (wall, m.stats().bytes_sent, shard.shard_bytes())
            })
        })
        .collect();
    let mut sent = 0u64;
    let mut wall = 0.0;
    let mut shard_bytes = 0usize;
    let n = joins.len();
    for j in joins {
        let (w, s, b) = j.join().unwrap();
        wall += w;
        sent += s;
        shard_bytes = b;
    }
    let t = rep.table(
        "real mesh (V=4096, H=256, 4 ranks, 512 tokens/rank)",
        &["metric", "partitioned", "replicated baseline"],
    );
    rep.row(t, vec![
        "per-rank table memory".into(),
        human_bytes(shard_bytes as u64),
        human_bytes((vocab * h * 4) as u64),
    ]);
    rep.row(t, vec![
        "bytes exchanged/rank/step".into(),
        human_bytes(sent / n as u64),
        human_bytes(2 * (vocab * h * 4) as u64), // allreduce of the grad
    ]);
    rep.row(t, vec![
        "fwd+bwd wall (mean, ms)".into(),
        format!("{:.2}", wall / n as f64 * 1e3),
        "-".into(),
    ]);
    rep.note("partitioned lookup verified element-exact against the full table");
}

fn main() {
    let mut rep = Report::new("table4_embedding_partition");
    paper_rows(&mut rep);
    real_mesh(&mut rep);
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
