//! Ablation — expert capacity factor: token drop rate, padding waste
//! and device load imbalance as the GShard capacity sweeps 1.0→2.0,
//! under balanced and Zipf-skewed routing (the regime Elastic MoE and
//! the aux loss fight). Pure-rust routing on real gating decisions.
//!
//! `cargo bench --bench ablation_capacity`.

use semoe::metrics::Report;
use semoe::moe::{top1_route, DispatchPlan, ExpertPlacement};
use semoe::util::rng::{Rng, ZipfTable};
use semoe::util::stats::imbalance;

fn logits(t: usize, e: usize, skew: Option<f64>, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut lg: Vec<f32> = (0..t * e).map(|_| rng.normal() as f32).collect();
    if let Some(s) = skew {
        // push each token toward a zipf-drawn favourite expert
        let zipf = ZipfTable::new(e, s);
        for ti in 0..t {
            let fav = zipf.sample(&mut rng);
            lg[ti * e + fav] += 3.0;
        }
    }
    lg
}

fn main() {
    let mut rep = Report::new("ablation_capacity");
    let (t_tokens, e) = (4096usize, 16usize);
    for (dist, skew) in [("balanced", None), ("zipf-1.1", Some(1.1))] {
        let tab = rep.table(
            &format!("capacity factor sweep — {} routing, {} tokens, {} experts", dist, t_tokens, e),
            &["cf", "capacity", "drop rate", "slot utilization", "device imbalance (4 dev)"],
        );
        for cf in [1.0f64, 1.25, 1.5, 2.0, 3.0] {
            let cap = ((cf * t_tokens as f64) / e as f64).ceil() as usize;
            let mut drops = 0usize;
            let mut used = 0usize;
            let mut imb = 0.0;
            let reps: usize = 3;
            for seed in 0..reps as u64 {
                let lg = logits(t_tokens, e, skew, seed);
                let r = top1_route(&lg, t_tokens, e, cap);
                drops += r.n_dropped();
                used += t_tokens - r.n_dropped();
                let placement = ExpertPlacement::contiguous(e, 4);
                let plan = DispatchPlan::build(&[r], &placement, 64);
                let loads: Vec<f64> = plan.recv_loads().iter().map(|&x| x as f64).collect();
                imb += imbalance(&loads);
            }
            rep.row(
                tab,
                vec![
                    format!("{:.2}", cf),
                    cap.to_string(),
                    format!("{:.2}%", drops as f64 / (reps * t_tokens) as f64 * 100.0),
                    format!("{:.1}%", used as f64 / (reps * e * cap) as f64 * 100.0),
                    format!("{:.2}", imb / reps as f64),
                ],
            );
        }
    }
    rep.note("cf=2.0 (the paper's default) eliminates drops under balanced routing but \
              wastes slots; under skew, capacity alone cannot fix device imbalance — \
              that is Elastic MoE's job (§4.1)");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
