//! Ablation — fusion communication: message count vs fused-chunk size
//! for the dense ZeRO-3 gather, measured on the real mesh (op counts,
//! bytes) and priced with a per-message software-latency model (the
//! quantity the paper's §2.3 optimizes).
//!
//! Also covers gradient buckets: bucket capacity vs number of
//! collectives per backward pass.
//!
//! `cargo bench --bench ablation_fusion`.

use semoe::comm::{FusionBuffer, GradientBuckets, Mesh};
use semoe::metrics::Report;

/// The dense parameter layout of one 12-layer model (tensor sizes in
/// elements), flattened: 14 dense tensors per layer.
fn dense_layout() -> Vec<(String, usize)> {
    let h = 256usize;
    let mut v = Vec::new();
    for l in 0..12 {
        for (n, len) in [
            ("ln1_s", h), ("ln1_b", h),
            ("wq", h * h), ("bq", h), ("wk", h * h), ("bk", h),
            ("wv", h * h), ("bv", h), ("wo", h * h), ("bo", h),
            ("ln2_s", h), ("ln2_b", h),
            ("router_w", h * 8), ("router_b", 8),
        ] {
            v.push((format!("l{}.{}", l, n), len));
        }
    }
    v
}

fn main() {
    let mut rep = Report::new("ablation_fusion");
    let layout = dense_layout();
    let total: usize = layout.iter().map(|(_, l)| l).sum();

    // ---- parameter fusion: chunk-size sweep
    let msg_lat = 30e-6; // per-collective software latency
    let wire_bw = 25e9; // bytes/s
    let t = rep.table(
        &format!("parameter fusion ({} tensors, {} elements total)", layout.len(), total),
        &["max chunk elems", "messages", "software ms", "wire ms", "total ms", "vs per-tensor"],
    );
    let per_tensor_total = layout.len() as f64 * msg_lat + (total * 4) as f64 / wire_bw;
    for max_chunk in [usize::MAX, 1 << 22, 1 << 20, 1 << 16, 1 << 12] {
        let mut fb = FusionBuffer::new();
        for (n, l) in &layout {
            fb.register(n, *l);
        }
        let chunks = fb.chunked(max_chunk.min(fb.len()));
        let n_msgs = chunks.len();
        let software = n_msgs as f64 * msg_lat;
        let wire = (total * 4) as f64 / wire_bw;
        rep.row(
            t,
            vec![
                if max_chunk == usize::MAX { "∞ (one msg)".into() } else { format!("{}", max_chunk) },
                n_msgs.to_string(),
                format!("{:.3}", software * 1e3),
                format!("{:.3}", wire * 1e3),
                format!("{:.3}", (software + wire) * 1e3),
                format!("{:.2}x", per_tensor_total / (software + wire)),
            ],
        );
    }
    rep.row(
        t,
        vec![
            "per-tensor (baseline)".into(),
            layout.len().to_string(),
            format!("{:.3}", layout.len() as f64 * msg_lat * 1e3),
            format!("{:.3}", (total * 4) as f64 / wire_bw * 1e3),
            format!("{:.3}", per_tensor_total * 1e3),
            "1.00x".into(),
        ],
    );

    // ---- gradient buckets: capacity sweep, real mesh collective count
    let t2 = rep.table(
        "gradient buckets (2-rank mesh, real allreduce count)",
        &["bucket capacity", "buckets", "collectives/pass"],
    );
    for cap in [usize::MAX, 1 << 20, 1 << 18, 1 << 14] {
        let mut gb = GradientBuckets::new(cap.min(total));
        for (n, l) in &layout {
            gb.register(n, *l);
        }
        let n_buckets = gb.n_buckets();
        // run a real pass over the mesh and count ops
        let handles = Mesh::new(2);
        let layout2 = layout.clone();
        let joins: Vec<_> = handles
            .into_iter()
            .map(|mut h| {
                let layout = layout2.clone();
                std::thread::spawn(move || {
                    let mut gb = GradientBuckets::new(cap.min(layout.iter().map(|(_, l)| l).sum()));
                    for (n, l) in &layout {
                        gb.register(n, *l);
                    }
                    gb.start_pass();
                    for (n, l) in layout.iter().rev() {
                        if let Some(ready) = gb.deposit(n, &vec![1.0f32; *l]) {
                            let mut fused = ready.data;
                            h.all_reduce_sum(&mut fused);
                        }
                    }
                    h.stats().ops
                })
            })
            .collect();
        let ops = joins.into_iter().map(|j| j.join().unwrap()).max().unwrap();
        rep.row(
            t2,
            vec![
                if cap == usize::MAX { "∞".into() } else { format!("{}", cap) },
                n_buckets.to_string(),
                ops.to_string(),
            ],
        );
    }
    rep.note("fewer, larger messages amortize per-collective latency; buckets trade memory \
              for deterministic aggregation order (§2.3)");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
