//! Table 3 — elastic MoE training on the UFO multi-task loads
//! (512/256/128/128): load-imbalanced one-GPU-per-task vs the elastic
//! 4/2/1/1 placement. Reports the analytic cask-effect model (pure +
//! fixed-overhead-calibrated) and, when enough cores exist, the
//! thread-emulated measurement. `cargo bench --bench table3_elastic`.

use semoe::config::presets::table3_setup;
use semoe::metrics::Report;
use semoe::train::elastic::simulate_throughput;
use semoe::train::{ElasticPlan, TaskLoad};

fn main() {
    let setup = table3_setup();
    let tasks: Vec<TaskLoad> = setup
        .task_batches
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskLoad { name: format!("task{}", i + 1), batch: b })
        .collect();
    let base = ElasticPlan::one_per_task(&tasks);
    let bal = ElasticPlan::balance(&tasks, 8);
    assert_eq!(bal.gpus_per_task, setup.balanced_gpus_per_task);

    let unit = 1e-3;
    let fixed = 153.5 * unit; // calibration: see elastic.rs tests

    let mut rep = Report::new("table3_elastic");
    let t = rep.table(
        "elastic MoE training (UFO, batches 512/256/128/128)",
        &["placement", "GPUs/task", "imbalance", "total samples/s", "per-card", "per-card (paper)"],
    );
    for (name, plan, paper) in [
        ("load imbalance", &base, setup.paper_imbalanced_speed_per_card),
        ("load balance (elastic)", &bal, setup.paper_balanced_speed_per_card),
    ] {
        let (total, per) = plan.throughput_with(unit, fixed);
        rep.row(
            t,
            vec![
                name.to_string(),
                format!("{:?}", plan.gpus_per_task),
                format!("{:.2}", plan.imbalance()),
                format!("{:.1}", total),
                format!("{:.1}", per),
                format!("{:.1}", paper),
            ],
        );
    }
    let (_, pb) = base.throughput_with(unit, fixed);
    let (_, pe) = bal.throughput_with(unit, fixed);
    rep.note(&format!(
        "per-card speedup {:.1}% (paper: +18.2%); pure cask-effect upper bound: {:.0}%",
        (pe / pb - 1.0) * 100.0,
        {
            let (_, a) = base.throughput(unit);
            let (_, b) = bal.throughput(unit);
            (b / a - 1.0) * 100.0
        }
    ));

    // Thread-emulated measurement (meaningful only with >= 8 cores).
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    if cores >= bal.total_gpus() {
        let (_, mb) = simulate_throughput(&base, 20e-6, 10);
        let (_, me) = simulate_throughput(&bal, 20e-6, 10);
        rep.note(&format!(
            "measured (threaded, {} cores): per-card {:.1} → {:.1} (+{:.1}%)",
            cores, mb, me, (me / mb - 1.0) * 100.0
        ));
    } else {
        rep.note(&format!(
            "threaded emulation skipped: {} core(s) < {} emulated GPUs (threads would timeshare)",
            cores,
            bal.total_gpus()
        ));
    }
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
