//! Micro-benchmarks of the runtime hot path: per-artifact execute times
//! on the `small` and `deep` presets, plus the H2D staging cost. This is
//! the baseline/after instrument of the §Perf pass (EXPERIMENTS.md).
//!
//! `cargo bench --bench micro_runtime`.

use std::rc::Rc;

use semoe::metrics::Report;
use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::util::stats::Summary;
use semoe::util::Rng;

fn bench_artifact(arts: &ModelArtifacts, name: &str, reps: usize) -> (f64, f64, usize) {
    let exe = arts.load_exe(name).expect(name);
    let mut rng = Rng::new(42);
    let inputs: Vec<HostTensor> = exe
        .spec
        .inputs
        .iter()
        .map(|s| match s.dtype {
            semoe::runtime::DType::F32 => HostTensor::randn(&s.shape, 0.05, &mut rng),
            semoe::runtime::DType::I32 => {
                let v = (0..s.numel()).map(|_| rng.below(16) as i32).collect();
                HostTensor::from_i32(&s.shape, v)
            }
        })
        .collect();
    let in_bytes: usize = inputs.iter().map(|t| t.byte_len()).sum();
    let _ = exe.run(&inputs).expect("warmup");
    let mut s = Summary::new();
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let _ = exe.run(&inputs).expect("run");
        s.add(t0.elapsed().as_secs_f64());
    }
    (s.mean(), s.std(), in_bytes)
}

fn main() {
    let mut rep = Report::new("micro_runtime");
    for preset in ["small", "deep"] {
        let arts = Rc::new(ModelArtifacts::load(preset).expect("artifacts"));
        let t = rep.table(
            &format!("artifact execute times — preset '{}'", preset),
            &["artifact", "mean ms", "std ms", "input bytes"],
        );
        let mut names = arts.artifact_names();
        names.retain(|n| n != "train_step" && n != "fwd_loss"); // benched separately below
        for name in names {
            let reps = 10;
            let (mean, std, bytes) = bench_artifact(&arts, &name, reps);
            rep.row(
                t,
                vec![
                    name.clone(),
                    format!("{:.3}", mean * 1e3),
                    format!("{:.3}", std * 1e3),
                    format!("{}", bytes),
                ],
            );
        }
        if arts.has("train_step") {
            let (mean, std, bytes) = bench_artifact(&arts, "train_step", 5);
            rep.row(
                t,
                vec![
                    "train_step".into(),
                    format!("{:.3}", mean * 1e3),
                    format!("{:.3}", std * 1e3),
                    format!("{}", bytes),
                ],
            );
        }
    }

    // H2D staging cost (Literal creation + buffer_from_host).
    let arts = ModelArtifacts::load("deep").expect("artifacts");
    let exe = arts.load_exe("layer_fwd").expect("layer_fwd");
    let mut rng = Rng::new(7);
    let big = HostTensor::randn(&[1 << 20], 1.0, &mut rng); // 4 MB
    let mut s = Summary::new();
    for _ in 0..20 {
        let t0 = std::time::Instant::now();
        let buf = exe.to_device(&big).expect("to_device");
        s.add(t0.elapsed().as_secs_f64());
        drop(buf);
    }
    let t = rep.table("H2D staging (4 MB tensor)", &["op", "mean ms", "GB/s"]);
    rep.row(
        t,
        vec![
            "to_device".into(),
            format!("{:.3}", s.mean() * 1e3),
            format!("{:.2}", 4e6 / s.mean() / 1e9),
        ],
    );
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
