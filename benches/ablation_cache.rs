//! Ablation — CPU-cache policy (Algorithm 1 vs plain LFU / LRU / FIFO):
//! hit rate, evictions and SSD writeback traffic under a Zipf-skewed
//! expert access pattern with periodic phase shifts (the regime the
//! moving-average decay of Algorithm 1 is designed for).
//!
//! `cargo bench --bench ablation_cache`.

use semoe::metrics::Report;
use semoe::storage::{CacheConfig, CachePolicy, CpuCache};
use semoe::util::rng::{Rng, ZipfTable};

/// Drive a cache through the system's REAL access pattern: training
/// steps of forward+backward layer sweeps, each layer touching the
/// expert blocks its tokens routed to (per-layer Zipf popularity).
/// Midway, the routing distribution drifts (the gating network keeps
/// learning) — the regime Algorithm 1's decay handles and plain LFU
/// does not. Returns (hit rate, dirty writebacks).
fn run(policy: CachePolicy, blocks: usize, steps: usize, seed: u64) -> (f64, u64) {
    let n_layers = 8usize;
    let experts_per_layer = 16usize;
    let touched_per_layer = 4usize; // active experts per step per layer
    let block = vec![0f32; 256];
    let mut cache = CpuCache::new(CacheConfig {
        capacity_bytes: blocks * block.len() * 4,
        policy,
        hit_threshold: 2.0,
        beta: 0.5,
        decay_every: 8,
    });
    let mut rng = Rng::new(seed);
    let zipf = ZipfTable::new(experts_per_layer, 1.4);
    // each layer has its own expert-popularity permutation
    let mut perms: Vec<Vec<usize>> = (0..n_layers)
        .map(|l| {
            let mut p: Vec<usize> = (0..experts_per_layer).collect();
            let mut r = Rng::new(seed * 1000 + l as u64);
            r.shuffle(&mut p);
            p
        })
        .collect();
    for step in 0..steps {
        if step == steps / 2 {
            // routing drift: the popularity orders reshuffle
            for (l, p) in perms.iter_mut().enumerate() {
                let mut r = Rng::new(seed * 7777 + l as u64);
                r.shuffle(p);
            }
        }
        // fwd sweep then bwd sweep (bwd re-touches + dirties the blocks)
        let sweep: Vec<usize> = (0..n_layers).chain((0..n_layers).rev()).collect();
        for (i, &l) in sweep.iter().enumerate() {
            let bwd = i >= n_layers;
            for _ in 0..touched_per_layer {
                let e = perms[l][zipf.sample(&mut rng)];
                let key = format!("l{}e{}", l, e);
                if cache.get(&key).is_none() {
                    let evicted = cache.insert(&key, block.clone(), bwd);
                    drop(evicted); // writeback accounted by cache stats
                } else if bwd {
                    cache.update(&key, block.clone());
                }
            }
        }
        cache.end_step();
    }
    let s = cache.stats();
    (s.hit_rate(), s.dirty_writebacks)
}

fn main() {
    let mut rep = Report::new("ablation_cache");
    for blocks in [16usize, 32, 64] {
        let t = rep.table(
            &format!("cache policy @ {} blocks (128 expert blocks, zipf 1.4, mid-run drift)", blocks),
            &["policy", "hit rate", "dirty writebacks"],
        );
        for (name, policy) in [
            ("Alg1 (LFU+threshold+decay)", CachePolicy::Alg1),
            ("LFU", CachePolicy::Lfu),
            ("LRU", CachePolicy::Lru),
            ("FIFO", CachePolicy::Fifo),
        ] {
            let mut hits = 0.0;
            let mut wb = 0u64;
            let reps = 5;
            for seed in 0..reps {
                let (h, w) = run(policy, blocks, 64, seed as u64);
                hits += h;
                wb += w;
            }
            rep.row(
                t,
                vec![
                    name.to_string(),
                    format!("{:.3}", hits / reps as f64),
                    format!("{}", wb / reps as u64),
                ],
            );
        }
    }
    rep.note("Algorithm 1's decay adapts to phase shifts that freeze plain LFU");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
