//! Figure 10 — ring-memory offload: inference performance w/ and w/o
//! overlapped offloading, plus the compute-vs-copy breakdown and the
//! device-memory saving.
//!
//! Two parts:
//!   1. REAL execution: the `deep` (12-layer) engine with a throttled
//!      copy stream, in resident / ring(K) / blocking(K=1) modes — the
//!      same code path a GPU deployment would run.
//!   2. Paper scale: the 58.2B / 32-expert model on 16×A100-40G via the
//!      pipeline-makespan simulator, including the K ablation.
//!
//! `cargo bench --bench fig10_ring_offload`.

use std::rc::Rc;

use semoe::config::presets::{cluster_for_gpus, fig10_model};
use semoe::infer::{InferMode, InferenceEngine};
use semoe::metrics::Report;
use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::sim::simulate_ring_offload;
use semoe::util::Rng;

fn measured(rep: &mut Report) {
    let arts = Rc::new(ModelArtifacts::load("deep").expect("deep artifacts"));
    let model = arts.preset.clone();
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..model.batch_size * model.seq_len)
        .map(|_| rng.below(model.vocab_size) as i32)
        .collect();
    let batch = HostTensor::from_i32(&[model.batch_size, model.seq_len], toks);

    // Throttle the copy stream to a "PCIe" that makes copies comparable
    // to this substrate's per-layer compute (~few ms).
    let layer_bytes = model.param_counts().per_layer as f64 * 4.0;
    let throttle = Some(layer_bytes / 4e-3); // ≈4 ms per layer copy

    let t = rep.table(
        "measured (deep preset, 12 layers, throttled copy stream)",
        &["mode", "pass ms", "compute ms", "copy ms", "stall ms", "device weights MB"],
    );
    let reps = 4;
    for (name, mode) in [
        ("resident", InferMode::Resident),
        ("ring K=4", InferMode::Ring { k: 4 }),
        ("ring K=2", InferMode::Ring { k: 2 }),
        ("blocking K=1", InferMode::Ring { k: 1 }),
    ] {
        let thr = if matches!(mode, InferMode::Resident) { None } else { throttle };
        let mut engine = InferenceEngine::new(arts.clone(), mode, 7, thr).expect("engine");
        let _ = engine.forward(&batch).expect("warmup");
        engine.timing = Default::default();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = engine.forward(&batch).expect("forward");
        }
        let pass = t0.elapsed().as_secs_f64() / reps as f64;
        let tm = engine.timing;
        rep.row(
            t,
            vec![
                name.to_string(),
                format!("{:.1}", pass * 1e3),
                format!("{:.1}", tm.compute_secs / reps as f64 * 1e3),
                format!("{:.1}", tm.copy_secs / reps as f64 * 1e3),
                format!("{:.1}", tm.stall_secs / reps as f64 * 1e3),
                format!("{:.1}", engine.device_weight_bytes() as f64 / 1e6),
            ],
        );
    }
}

fn paper_scale(rep: &mut Report) {
    let m = fig10_model();
    let mut cl = cluster_for_gpus(16);
    cl.gpu_mem = 40 * (1 << 30); // the paper's A100-40G testbed
    let t = rep.table(
        "paper scale (58.2B, 32 experts, 16×A100-40G, simulated)",
        &["K", "resident ms", "ring ms", "blocking ms", "ring overhead", "mem GB (resident→ring)"],
    );
    for k in [1usize, 2, 4, 8] {
        let r = simulate_ring_offload(&m, &cl, k);
        rep.row(
            t,
            vec![
                k.to_string(),
                format!("{:.1}", r.t_resident * 1e3),
                format!("{:.1}", r.t_ring * 1e3),
                format!("{:.1}", r.t_blocking * 1e3),
                format!("{:.1}%", (r.t_ring / r.t_resident - 1.0) * 100.0),
                format!("{:.1} → {:.1}", r.mem_resident / 1e9, r.mem_ring / 1e9),
            ],
        );
    }
    rep.note("paper: overlapped offload ≈ unaffected performance, ≥30% less GPU memory");
}

fn main() {
    let mut rep = Report::new("fig10_ring_offload");
    measured(&mut rep);
    paper_scale(&mut rep);
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
