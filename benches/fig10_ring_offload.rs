//! Figure 10 — ring-memory offload: inference performance w/ and w/o
//! overlapped offloading, plus the compute-vs-copy breakdown and the
//! device-memory saving.
//!
//! Three parts:
//!   1. REAL execution: the `deep` (12-layer) engine with a throttled
//!      copy stream, in resident / ring(K) / blocking(K=1) modes — the
//!      same code path a GPU deployment would run — plus the
//!      routed-vs-dense ring comparison (bit-identical outputs, copy
//!      bytes accounted).
//!   2. Routed-vs-dense ablation on a synthetic expert ring: plans
//!      sampled from uniform vs Zipf routing drive `RingMemory`
//!      directly; under skew the routed pass must move strictly fewer
//!      bytes (asserted — the tentpole claim, measured).
//!   3. Paper scale: the 58.2B / 32-expert model on 16×A100-40G via the
//!      pipeline-makespan simulator, including the K ablation.
//!
//! `cargo bench --bench fig10_ring_offload`; `SEMOE_SMOKE=1` runs the
//! same assertions at reduced repetition counts (tier-1 CI).

use std::rc::Rc;

use semoe::config::presets::{cluster_for_gpus, fig10_model};
use semoe::infer::ring_memory::{LayerLoader, RingMemory, StageKind};
use semoe::infer::{InferMode, InferenceEngine, PipelineConfig, RoutedRingConfig};
use semoe::metrics::Report;
use semoe::prefetch::RoutePlan;
use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::sim::{simulate_pipelined_ring, simulate_ring_offload, simulate_routed_ring};
use semoe::util::rng::ZipfTable;
use semoe::util::Rng;

fn smoke() -> bool {
    std::env::var("SEMOE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn measured(rep: &mut Report) {
    let arts = Rc::new(ModelArtifacts::load("deep").expect("deep artifacts"));
    let model = arts.preset.clone();
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..model.batch_size * model.seq_len)
        .map(|_| rng.below(model.vocab_size) as i32)
        .collect();
    let batch = HostTensor::from_i32(&[model.batch_size, model.seq_len], toks);

    // Throttle the copy stream to a "PCIe" that makes copies comparable
    // to this substrate's per-layer compute (~few ms).
    let layer_bytes = model.param_counts().per_layer as f64 * 4.0;
    let throttle = Some(layer_bytes / 4e-3); // ≈4 ms per layer copy

    let t = rep.table(
        "measured (deep preset, 12 layers, throttled copy stream)",
        &["mode", "pass ms", "compute ms", "copy ms", "stall ms", "overlap ms", "plan ms",
          "tail ms", "device weights MB"],
    );
    let reps = if smoke() { 1 } else { 4 };
    for (name, mode, routed, pipelined) in [
        ("resident", InferMode::Resident, false, false),
        ("ring K=4", InferMode::Ring { k: 4 }, false, false),
        ("ring K=2", InferMode::Ring { k: 2 }, false, false),
        ("ring K=2 routed", InferMode::Ring { k: 2 }, true, false),
        ("ring K=2 pipelined", InferMode::Ring { k: 2 }, false, true),
        ("blocking K=1", InferMode::Ring { k: 1 }, false, false),
    ] {
        let thr = if matches!(mode, InferMode::Resident) { None } else { throttle };
        let mut engine = InferenceEngine::new(arts.clone(), mode, 7, thr).expect("engine");
        if routed {
            engine.set_routed(RoutedRingConfig { enabled: true, hot_frac: 0.5 });
        }
        if pipelined {
            engine.set_pipelined(PipelineConfig { enabled: true, hot_frac: 0.5 });
        }
        let _ = engine.forward(&batch).expect("warmup");
        engine.timing = Default::default();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = engine.forward(&batch).expect("forward");
        }
        let pass = t0.elapsed().as_secs_f64();
        let tm = engine.timing;
        rep.row(
            t,
            vec![
                name.to_string(),
                format!("{:.1}", pass / reps as f64 * 1e3),
                format!("{:.1}", tm.compute_secs / reps as f64 * 1e3),
                format!("{:.1}", tm.copy_secs / reps as f64 * 1e3),
                format!("{:.1}", tm.stall_secs / reps as f64 * 1e3),
                format!("{:.1}", tm.overlap_secs / reps as f64 * 1e3),
                // contract v2: plan/parse time replaces the old shadow-
                // recompute column (shadow_secs is asserted 0 below);
                // contract v3: tail ms is the tail-only repair compute
                format!("{:.1}", tm.plan_secs / reps as f64 * 1e3),
                format!("{:.1}", tm.tail_secs / reps as f64 * 1e3),
                format!("{:.1}", engine.device_weight_bytes() as f64 / 1e6),
            ],
        );
    }
}

/// Routed vs dense ring passes on the REAL engine, same seeded
/// workload: outputs must be bit-identical and the routed copy lane
/// (including demand repairs) may never move more bytes than dense.
fn routed_engine(rep: &mut Report) {
    let arts = Rc::new(ModelArtifacts::load("deep").expect("deep artifacts"));
    let model = arts.preset.clone();
    let n_new = if smoke() { 2 } else { 4 };
    let prompts: Vec<Vec<i32>> =
        (0..model.batch_size).map(|i| vec![i as i32 * 5 + 3; 6]).collect();

    let mut dense = InferenceEngine::new(arts.clone(), InferMode::Ring { k: 3 }, 7, None).unwrap();
    let mut routed = InferenceEngine::new(arts.clone(), InferMode::Ring { k: 3 }, 7, None).unwrap();
    routed.set_routed(RoutedRingConfig { enabled: true, hot_frac: 0.5 });

    let a = dense.generate(&prompts, n_new).expect("dense generate");
    let b = routed.generate(&prompts, n_new).expect("routed generate");
    assert_eq!(a, b, "routed ring passes must decode bit-identically to dense");

    let db = dense.ring_stats().unwrap().copy_bytes;
    let rb = routed.ring_stats().unwrap().copy_bytes;
    let rs = routed.route_stats();
    assert!(
        rb + rs.repair_bytes <= db,
        "routed + repairs must not exceed dense bytes: {} + {} vs {}",
        rb,
        rs.repair_bytes,
        db
    );
    // Contract-v2 acceptance: routed planning/repair never invokes the
    // f64 shadow recompute — the exact sets come out of the kernel, and
    // consecutive passes plan from the previous pass's emitted sets.
    assert_eq!(
        routed.timing.shadow_secs, 0.0,
        "no shadow MHA may run on the routed hot path"
    );
    // Contract-v3 acceptance: a plan miss repairs the expert tail only —
    // a full-layer re-run (attention included) never happens.
    assert_eq!(
        rs.rerun_layers, 0,
        "tail-only repair: no full-layer re-runs on the routed hot path"
    );
    assert!(
        rs.carried_plans >= n_new as u64 - 1,
        "passes after the first must carry kernel-emitted plans: {} of {}",
        rs.carried_plans,
        n_new
    );
    // Pipelined split pass on the same workload: layer_dense runs while
    // the ring stages only the expert subset, one expert_tail per layer.
    let mut piped = InferenceEngine::new(arts.clone(), InferMode::Ring { k: 3 }, 7, None).unwrap();
    piped.set_pipelined(PipelineConfig { enabled: true, hot_frac: 0.5 });
    let c = piped.generate(&prompts, n_new).expect("pipelined generate");
    assert_eq!(a, c, "pipelined split passes must decode bit-identically to fused");
    let pb = piped.ring_stats().unwrap().copy_bytes;
    let ps = piped.route_stats();
    assert!(
        pb + ps.repair_bytes < db,
        "sparse-only staging must undercut the dense pass: {} + {} vs {}",
        pb,
        ps.repair_bytes,
        db
    );
    // The split actually executed: every layer of every pass ran its
    // dense prefix, and by construction no expert tail ever re-ran.
    assert!(ps.dense_prefix_layers > 0, "layer_dense must execute on the pipelined path");
    assert_eq!(ps.rerun_tails, 0, "pipelined passes are exact by construction");

    let t = rep.table(
        "routed vs dense ring (deep preset, identical outputs asserted)",
        &["pass", "copy MB", "repair MB", "planned experts", "exact experts", "repaired",
          "tail reruns"],
    );
    rep.row(
        t,
        vec![
            "dense".into(),
            format!("{:.2}", db as f64 / 1e6),
            "0.00".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ],
    );
    rep.row(
        t,
        vec![
            "routed".into(),
            format!("{:.2}", rb as f64 / 1e6),
            format!("{:.2}", rs.repair_bytes as f64 / 1e6),
            rs.planned_experts.to_string(),
            rs.exact_experts.to_string(),
            rs.repaired_experts.to_string(),
            rs.rerun_tails.to_string(),
        ],
    );
    rep.row(
        t,
        vec![
            "pipelined".into(),
            format!("{:.2}", pb as f64 / 1e6),
            format!("{:.2}", ps.repair_bytes as f64 / 1e6),
            ps.planned_experts.to_string(),
            ps.exact_experts.to_string(),
            ps.repaired_experts.to_string(),
            ps.rerun_tails.to_string(),
        ],
    );
}

/// Routed-vs-dense byte ablation on a synthetic expert ring: `RingMemory`
/// driven directly with plans sampled from uniform vs Zipf(1.2) routing.
/// The skewed routed pass must move strictly fewer bytes than both the
/// dense pass and the uniform routed pass — the paper's
/// unbalanced-workload win, measured on the actual copy lane.
fn routed_ablation(rep: &mut Report) {
    const LAYERS: usize = 8;
    const EXPERTS: usize = 16;
    const DENSE: usize = 512; // dense-member f32s per layer
    const PER_EXPERT: usize = 1024; // f32s per expert per layer
    const TOKENS: usize = 32; // routing decisions per layer per pass

    let mk_loader = || -> LayerLoader {
        Box::new(move |l, experts: Option<&[usize]>, kind: StageKind| {
            // Sparse-only staging (pipelined passes): dense members ride
            // as zero-filled placeholders and cost no copy bytes.
            let (dense, mut copied) = match kind {
                StageKind::Full => {
                    (HostTensor::from_f32(&[DENSE], vec![l as f32; DENSE]), DENSE * 4)
                }
                StageKind::SparseOnly => (HostTensor::from_f32(&[DENSE], vec![0.0; DENSE]), 0),
            };
            let mut data = vec![0f32; EXPERTS * PER_EXPERT];
            let all: Vec<usize> = (0..EXPERTS).collect();
            for &e in experts.unwrap_or(&all) {
                data[e * PER_EXPERT..(e + 1) * PER_EXPERT].fill((l * 100 + e) as f32);
                copied += PER_EXPERT * 4;
            }
            (vec![dense, HostTensor::from_f32(&[EXPERTS, PER_EXPERT], data)], copied)
        })
    };
    let passes = if smoke() { 2 } else { 8 };
    let run = |zipf_s: Option<f64>, kind: StageKind| -> u64 {
        let mut ring = RingMemory::new(3, LAYERS, mk_loader(), None);
        ring.set_stage_kind(kind);
        let zipf = zipf_s.map(|s| ZipfTable::new(EXPERTS, s));
        let mut rng = Rng::new(11);
        for _ in 0..passes {
            let plan = zipf.as_ref().map(|z| {
                let per_layer: Vec<Vec<usize>> = (0..LAYERS)
                    .map(|_| {
                        let mut set: Vec<usize> =
                            (0..TOKENS).map(|_| z.sample(&mut rng)).collect();
                        set.sort_unstable();
                        set.dedup();
                        set
                    })
                    .collect();
                RoutePlan::new(per_layer, &[])
            });
            ring.begin_pass(plan.as_ref());
            for l in 0..LAYERS {
                let _ = ring.get(l).unwrap();
                ring.release(l);
            }
        }
        ring.stats().copy_bytes
    };
    let dense = run(None, StageKind::Full);
    let uniform = run(Some(0.0), StageKind::Full);
    let skew = run(Some(1.2), StageKind::Full);
    let sparse_only = run(Some(1.2), StageKind::SparseOnly);

    let t = rep.table(
        &format!(
            "routed vs dense ring bytes ({} layers × {} experts, {} tokens/layer, {} passes)",
            LAYERS, EXPERTS, TOKENS, passes
        ),
        &["pass plan", "copy MB", "vs dense"],
    );
    for (name, bytes) in [
        ("dense", dense),
        ("routed uniform", uniform),
        ("routed zipf 1.2", skew),
        ("pipelined zipf 1.2", sparse_only),
    ] {
        rep.row(
            t,
            vec![
                name.to_string(),
                format!("{:.2}", bytes as f64 / 1e6),
                format!("{:.2}x", bytes as f64 / dense as f64),
            ],
        );
    }
    assert!(
        skew < dense,
        "routed ring pass must copy strictly fewer bytes than dense under skew: {} vs {}",
        skew,
        dense
    );
    assert!(uniform <= dense, "routed can never exceed dense: {} vs {}", uniform, dense);
    assert!(
        skew < uniform,
        "skew must shrink the routed set below uniform: {} vs {}",
        skew,
        uniform
    );
    assert!(
        sparse_only < skew,
        "sparse-only staging must drop the dense bytes too: {} vs {}",
        sparse_only,
        skew
    );
}

fn paper_scale(rep: &mut Report) {
    let m = fig10_model();
    let mut cl = cluster_for_gpus(16);
    cl.gpu_mem = 40 * (1 << 30); // the paper's A100-40G testbed
    let t = rep.table(
        "paper scale (58.2B, 32 experts, 16×A100-40G, simulated)",
        &["K", "resident ms", "ring ms", "blocking ms", "ring overhead", "mem GB (resident→ring)"],
    );
    for k in [1usize, 2, 4, 8] {
        let r = simulate_ring_offload(&m, &cl, k);
        rep.row(
            t,
            vec![
                k.to_string(),
                format!("{:.1}", r.t_resident * 1e3),
                format!("{:.1}", r.t_ring * 1e3),
                format!("{:.1}", r.t_blocking * 1e3),
                format!("{:.1}%", (r.t_ring / r.t_resident - 1.0) * 100.0),
                format!("{:.1} → {:.1}", r.mem_resident / 1e9, r.mem_ring / 1e9),
            ],
        );
    }
    // Routed ring at paper scale: a 64-token live decode batch, uniform
    // vs Zipf-skewed expert popularity.
    let t2 = rep.table(
        "paper scale routed ring (K=4, 64-token live batch, simulated)",
        &["routing", "E[distinct experts]", "copy GB/pass", "ring ms", "vs dense"],
    );
    for (name, s) in [("uniform", 0.0), ("zipf s=1.2", 1.2)] {
        let r = simulate_routed_ring(&m, &cl, 4, 64.0, s);
        rep.row(
            t2,
            vec![
                name.to_string(),
                format!("{:.1}/{}", r.expected_experts, m.n_experts),
                format!("{:.2}", r.bytes_routed / 1e9),
                format!("{:.1}", r.t_ring_routed * 1e3),
                // bytes_dense is token/skew-independent — the per-row
                // report already carries the dense reference
                format!("{:.2}x", r.bytes_routed / r.bytes_dense),
            ],
        );
        assert!(r.bytes_routed <= r.bytes_dense);
    }
    // Pipelined split passes at paper scale: a copy-bound PCIe lane
    // (1/16 bandwidth) is the regime the dense/sparse overlap is built
    // for — the pipelined pass must beat the fused routed pass outright
    // under Zipf skew.
    let mut slow = cl.clone();
    slow.pcie.bandwidth /= 16.0;
    let t3 = rep.table(
        "paper scale pipelined ring (K=4, 64-token live batch, 1/16 PCIe, simulated)",
        &["routing", "fused ms", "pipelined ms", "speedup", "overlap ms"],
    );
    for (name, s) in [("uniform", 0.0), ("zipf s=1.2", 1.2)] {
        let r = simulate_pipelined_ring(&m, &slow, 4, 64.0, s);
        rep.row(
            t3,
            vec![
                name.to_string(),
                format!("{:.1}", r.t_fused * 1e3),
                format!("{:.1}", r.t_pipelined * 1e3),
                format!("{:.2}x", r.speedup()),
                format!("{:.1}", r.overlap_secs * 1e3),
            ],
        );
        assert!(r.t_pipelined <= r.t_fused + 1e-12, "pipelining never loses");
        if s > 0.0 {
            assert!(
                r.t_pipelined < r.t_fused,
                "pipelined pass must beat fused under skew on a copy-bound lane: {:.4} vs {:.4}",
                r.t_pipelined,
                r.t_fused
            );
        }
    }
    rep.note("paper: overlapped offload ≈ unaffected performance, ≥30% less GPU memory; routed passes additionally shrink the copy lane to the live batch's expert working set; pipelined split passes hide that copy behind the dense prefix");
}

fn main() {
    let mut rep = Report::new("fig10_ring_offload");
    measured(&mut rep);
    routed_engine(&mut rep);
    routed_ablation(&mut rep);
    paper_scale(&mut rep);
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
