//! Table 1 — large-scale MoE training throughput + per-rank memory,
//! DeepSpeed-like baseline vs SE-MoE, all five paper rows.
//!
//! The substrate is the calibrated cluster simulator (byte volumes and
//! schedule structure exact, device constants calibrated; see
//! DESIGN.md §Substitutions). Paper numbers are printed alongside for
//! shape comparison. `cargo bench --bench table1_training`.

use semoe::config::presets::{cluster_for_gpus, table1_model, table1_rows};
use semoe::metrics::Report;
use semoe::sim::{simulate_training, Schedule};

fn main() {
    let mut rep = Report::new("table1_training");
    let t = rep.table(
        "MoE-GPT training throughput (tokens/s) and per-rank memory (GB)",
        &[
            "params", "experts", "GPUs",
            "DS tok/s (sim)", "SE tok/s (sim)", "speedup (sim)", "speedup (paper)",
            "DS GB (sim)", "SE GB (sim)", "mem ratio (sim)", "mem ratio (paper)",
        ],
    );
    for row in table1_rows() {
        let m = table1_model(row.n_experts, row.batch_size);
        let cl = cluster_for_gpus(row.gpus);
        let ds = simulate_training(&m, &cl, Schedule::DeepSpeedLike);
        let se = simulate_training(&m, &cl, Schedule::SeMoe);
        rep.row(
            t,
            vec![
                format!("{:.1}B", row.params_b),
                row.n_experts.to_string(),
                row.gpus.to_string(),
                format!("{:.0}", ds.tokens_per_s),
                format!("{:.0}", se.tokens_per_s),
                format!("{:.2}x", se.tokens_per_s / ds.tokens_per_s),
                format!("{:.2}x", row.paper_semoe_tps / row.paper_deepspeed_tps),
                format!("{:.1}", ds.gpu_mem_gb),
                format!("{:.1}", se.gpu_mem_gb),
                format!("{:.2}", se.gpu_mem_gb / ds.gpu_mem_gb),
                format!("{:.2}", row.paper_semoe_mem_gb / row.paper_deepspeed_mem_gb),
            ],
        );
    }
    let b = rep.table(
        "SE-MoE step breakdown (ms)",
        &["GPUs", "compute", "alltoall", "dense comm", "overhead"],
    );
    for row in table1_rows() {
        let m = table1_model(row.n_experts, row.batch_size);
        let se = simulate_training(&m, &cluster_for_gpus(row.gpus), Schedule::SeMoe);
        rep.row(
            b,
            vec![
                row.gpus.to_string(),
                format!("{:.1}", se.t_compute * 1e3),
                format!("{:.1}", se.t_a2a * 1e3),
                format!("{:.1}", se.t_dense * 1e3),
                format!("{:.1}", se.t_overhead * 1e3),
            ],
        );
    }
    rep.note("simulator: calibrated cost model (DESIGN.md §Substitutions); absolute \
              tokens/s differ from the paper's A100 testbed, ratios are the target");
    rep.note("paper speedups: 1.28x (8 GPU) to 1.33x (128 GPU); paper memory ratio ≈ 0.82");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
