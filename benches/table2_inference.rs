//! Table 2 — MoE inference throughput, DeepSpeed vs SE-MoE, at the
//! paper's three scales (10B / 106.5B / 209.6B), plus a REAL measured
//! row: the `deep` preset engine on the CPU-PJRT substrate, fused-kernel
//! path vs per-op overhead emulation. `cargo bench --bench table2_inference`.

use std::rc::Rc;

use semoe::config::presets::{cluster_for_gpus, table2_model, table2_rows};
use semoe::infer::{InferMode, InferenceEngine};
use semoe::metrics::Report;
use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::sim::simulate_inference;
use semoe::util::Rng;

fn main() {
    let mut rep = Report::new("table2_inference");
    let t = rep.table(
        "MoE inference throughput (tokens/s)",
        &["params", "GPUs", "batch", "DS (sim)", "SE (sim)", "speedup (sim)", "speedup (paper)"],
    );
    for row in table2_rows() {
        let m = table2_model(row.params_b, row.batch_size);
        let cl = cluster_for_gpus(row.gpus);
        let ds = simulate_inference(&m, &cl, false);
        let se = simulate_inference(&m, &cl, true);
        rep.row(
            t,
            vec![
                format!("{:.1}B", row.params_b),
                row.gpus.to_string(),
                row.batch_size.to_string(),
                format!("{:.0}", ds.tokens_per_s),
                format!("{:.0}", se.tokens_per_s),
                format!("{:.2}x", se.tokens_per_s / ds.tokens_per_s),
                format!("{:.2}x", row.paper_semoe_tps / row.paper_deepspeed_tps),
            ],
        );
    }

    // ---- measured row: real engine, real artifacts.
    let arts = Rc::new(ModelArtifacts::load("deep").expect("deep artifacts"));
    let model = arts.preset.clone();
    let mut engine = InferenceEngine::new(arts, InferMode::Resident, 7, None).expect("engine");
    let mut rng = Rng::new(3);
    let toks: Vec<i32> = (0..model.batch_size * model.seq_len)
        .map(|_| rng.below(model.vocab_size) as i32)
        .collect();
    let batch = HostTensor::from_i32(&[model.batch_size, model.seq_len], toks);
    let _ = engine.forward(&batch).expect("warmup");
    let reps = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let _ = engine.forward(&batch).expect("forward");
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    let tps = model.tokens_per_batch() as f64 / secs;
    let m = rep.table(
        "measured (CPU-PJRT substrate, deep preset)",
        &["preset", "params", "forward ms", "tokens/s"],
    );
    rep.row(
        m,
        vec![
            model.name.clone(),
            format!("{:.1}M", model.param_counts().total as f64 / 1e6),
            format!("{:.1}", secs * 1e3),
            format!("{:.0}", tps),
        ],
    );
    rep.note("sim rows reproduce the paper's ratio; measured row grounds the substrate");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
