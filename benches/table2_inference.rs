//! Table 2 — MoE inference throughput, DeepSpeed vs SE-MoE, at the
//! paper's three scales (10B / 106.5B / 209.6B), plus a REAL measured
//! row: the `deep` preset engine on the CPU-PJRT substrate. Extended
//! with the serving-schedule comparison behind `infer::session`:
//! batch-synchronous vs continuous batching on a mixed-length workload,
//! both simulated (busy-step accounting) and measured end-to-end on the
//! real engine. `cargo bench --bench table2_inference`.

use std::rc::Rc;

use semoe::config::presets::{cluster_for_gpus, fig10_model, table2_model, table2_rows};
use semoe::infer::{InferMode, InferenceEngine, ServeSession, SessionConfig};
use semoe::metrics::{Registry, Report};
use semoe::runtime::{HostTensor, ModelArtifacts};
use semoe::sim::{
    simulate_inference, simulate_pipelined_ring, simulate_routed_ring, simulate_serving,
    ServeRequest,
};
use semoe::util::Rng;

fn smoke() -> bool {
    std::env::var("SEMOE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let mut rep = Report::new("table2_inference");
    let t = rep.table(
        "MoE inference throughput (tokens/s)",
        &["params", "GPUs", "batch", "DS (sim)", "SE (sim)", "speedup (sim)", "speedup (paper)"],
    );
    for row in table2_rows() {
        let m = table2_model(row.params_b, row.batch_size);
        let cl = cluster_for_gpus(row.gpus);
        let ds = simulate_inference(&m, &cl, false);
        let se = simulate_inference(&m, &cl, true);
        rep.row(
            t,
            vec![
                format!("{:.1}B", row.params_b),
                row.gpus.to_string(),
                row.batch_size.to_string(),
                format!("{:.0}", ds.tokens_per_s),
                format!("{:.0}", se.tokens_per_s),
                format!("{:.2}x", se.tokens_per_s / ds.tokens_per_s),
                format!("{:.2}x", row.paper_semoe_tps / row.paper_deepspeed_tps),
            ],
        );
    }

    // ---- serving schedule (sim): batch-synchronous vs continuous
    // batching on a bursty mixed-length workload, 8 slots. Time unit is
    // one decode step (a full layer walk), so tokens/step is the
    // device-efficiency metric.
    let mut rng = Rng::new(9);
    let workload: Vec<ServeRequest> = (0..64)
        .map(|i| ServeRequest {
            arrive_step: (i / 8) * 3,
            decode_steps: 2 + rng.below(40),
        })
        .collect();
    let cmp = simulate_serving(&workload, 8);
    let st = rep.table(
        "serving schedule (sim): 64 mixed-length requests, 8 slots",
        &["schedule", "busy steps", "tokens/step", "utilization", "mean lat (steps)", "p95 lat"],
    );
    for (name, r) in [("batch-synchronous", &cmp.synchronous), ("continuous", &cmp.continuous)] {
        rep.row(
            st,
            vec![
                name.to_string(),
                r.busy_steps.to_string(),
                format!("{:.2}", r.tokens_per_step()),
                format!("{:.0}%", r.utilization() * 100.0),
                format!("{:.1}", r.mean_latency_steps),
                format!("{:.1}", r.p95_latency_steps),
            ],
        );
    }
    println!(
        "serving sim: continuous batching {:.2}x tokens/step vs batch-synchronous",
        cmp.speedup()
    );
    assert!(
        cmp.speedup() >= 1.0,
        "continuous batching must not lose to batch-synchronous"
    );

    // ---- routed-vs-dense ring pricing under the serving regime: the
    // bytes a ring pass copies when it stages only the live batch's
    // expected expert working set (uniform vs Zipf-skewed routing, the
    // UFO-style unbalanced workload), at paper scale.
    let routed_model = fig10_model(); // 32 experts — the offload testbed
    let routed_cl = cluster_for_gpus(16);
    let rt = rep.table(
        "routed ring pricing (58.2B, 32 experts, K=4): live decode batches",
        &["live tokens", "routing", "E[distinct experts]", "copy GB/pass", "vs dense"],
    );
    let mut zipf_vs_dense = (0.0f64, 0.0f64); // (routed zipf bytes, dense bytes)
    for tokens in [8.0f64, 64.0] {
        for (routing, s) in [("uniform", 0.0), ("zipf s=1.2", 1.2)] {
            let r = simulate_routed_ring(&routed_model, &routed_cl, 4, tokens, s);
            if tokens > 32.0 && s > 0.0 {
                zipf_vs_dense = (r.bytes_routed, r.bytes_dense);
            }
            rep.row(
                rt,
                vec![
                    format!("{:.0}", tokens),
                    routing.to_string(),
                    format!("{:.1}/{}", r.expected_experts, routed_model.n_experts),
                    format!("{:.2}", r.bytes_routed / 1e9),
                    // bytes_dense is token/skew-independent: any row's
                    // report carries the same dense reference
                    format!("{:.2}x", r.bytes_routed / r.bytes_dense),
                ],
            );
        }
    }
    assert!(
        zipf_vs_dense.0 < zipf_vs_dense.1,
        "routed ring pass must price strictly below dense under Zipf skew: {} vs {}",
        zipf_vs_dense.0,
        zipf_vs_dense.1
    );

    // ---- pipelined-vs-fused pass pricing under the same serving
    // regime: dense prefix executes while only the expert subset
    // streams. On a copy-bound lane (1/16 PCIe) the split pass must
    // beat the fused routed pass outright under Zipf skew.
    let mut slow_cl = routed_cl.clone();
    slow_cl.pcie.bandwidth /= 16.0;
    let pt = rep.table(
        "pipelined ring pricing (58.2B, K=4, 1/16 PCIe): fused vs split passes",
        &["live tokens", "routing", "fused ms", "pipelined ms", "speedup"],
    );
    for tokens in [8.0f64, 64.0] {
        for (routing, s) in [("uniform", 0.0), ("zipf s=1.2", 1.2)] {
            let r = simulate_pipelined_ring(&routed_model, &slow_cl, 4, tokens, s);
            rep.row(
                pt,
                vec![
                    format!("{:.0}", tokens),
                    routing.to_string(),
                    format!("{:.1}", r.t_fused * 1e3),
                    format!("{:.1}", r.t_pipelined * 1e3),
                    format!("{:.2}x", r.speedup()),
                ],
            );
            assert!(r.t_pipelined <= r.t_fused + 1e-12, "pipelining never loses");
            if s > 0.0 {
                assert!(
                    r.t_pipelined < r.t_fused,
                    "pipelined pass must beat fused under Zipf skew: {:.4} vs {:.4}",
                    r.t_pipelined,
                    r.t_fused
                );
            }
        }
    }

    // ---- measured rows: real engine, real artifacts.
    let arts = Rc::new(ModelArtifacts::load("deep").expect("deep artifacts"));
    let model = arts.preset.clone();
    let mut engine = InferenceEngine::new(arts.clone(), InferMode::Resident, 7, None).expect("engine");
    let mut rng = Rng::new(3);
    let toks: Vec<i32> = (0..model.batch_size * model.seq_len)
        .map(|_| rng.below(model.vocab_size) as i32)
        .collect();
    let batch = HostTensor::from_i32(&[model.batch_size, model.seq_len], toks);
    let _ = engine.forward(&batch).expect("warmup");
    let reps = if smoke() { 2 } else { 5 };
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let _ = engine.forward(&batch).expect("forward");
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    let tps = model.tokens_per_batch() as f64 / secs;
    let m = rep.table(
        "measured (CPU-PJRT substrate, deep preset)",
        &["preset", "params", "forward ms", "tokens/s"],
    );
    rep.row(
        m,
        vec![
            model.name.clone(),
            format!("{:.1}M", model.param_counts().total as f64 / 1e6),
            format!("{:.1}", secs * 1e3),
            format!("{:.0}", tps),
        ],
    );

    // ---- measured serving comparison on the SAME engine weights: a
    // mixed-length request set, batch-synchronous (pad to B, run to the
    // longest member) vs the slot session (admit/retire between steps).
    let b = model.batch_size;
    let budgets: Vec<usize> = (0..3 * b).map(|i| 1 + (i % 3) * 4).collect(); // 1/5/9 tokens
    let prompts: Vec<Vec<i32>> = (0..3 * b).map(|i| vec![i as i32 + 1; 4]).collect();
    let useful: usize = budgets.iter().sum();

    // batch-synchronous baseline: groups of B, lock-step to max budget
    let t0 = std::time::Instant::now();
    let mut sync_steps = 0usize;
    for g in 0..3 {
        let group: Vec<Vec<i32>> = prompts[g * b..(g + 1) * b].to_vec();
        let max_new = budgets[g * b..(g + 1) * b].iter().max().copied().unwrap();
        let _ = engine.generate(&group, max_new).expect("sync generate");
        sync_steps += max_new;
    }
    let sync_secs = t0.elapsed().as_secs_f64();

    // continuous: same engine moves into a ServeSession
    let mut session = ServeSession::new(engine, SessionConfig::default(), Registry::new());
    let t0 = std::time::Instant::now();
    for (i, (p, &n)) in prompts.iter().zip(&budgets).enumerate() {
        session.submit(i as u64 + 1, p.clone(), n).expect("submit");
    }
    let done = session.run_to_idle().expect("drain");
    let cont_secs = t0.elapsed().as_secs_f64();
    assert_eq!(done.len(), 3 * b);
    let cont_steps = session.stats().steps as usize;

    let sv = rep.table(
        "measured serving (deep preset): 12 mixed-length requests, 4 slots",
        &["schedule", "decode steps", "wall s", "useful tokens/s"],
    );
    rep.row(
        sv,
        vec![
            "batch-synchronous".into(),
            sync_steps.to_string(),
            format!("{:.2}", sync_secs),
            format!("{:.0}", useful as f64 / sync_secs),
        ],
    );
    rep.row(
        sv,
        vec![
            "continuous".into(),
            cont_steps.to_string(),
            format!("{:.2}", cont_secs),
            format!("{:.0}", useful as f64 / cont_secs),
        ],
    );
    let gain = (useful as f64 / cont_secs) / (useful as f64 / sync_secs);
    println!(
        "measured serving: continuous {} steps vs synchronous {} steps → {:.2}x useful tokens/s",
        cont_steps, sync_steps, gain
    );
    assert!(
        cont_steps <= sync_steps,
        "slot scheduling must not take more layer walks than lock-step batching"
    );

    rep.note("sim rows reproduce the paper's ratio; measured rows ground the substrate; serving rows price the continuous-batching engine");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
