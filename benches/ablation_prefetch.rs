//! Ablation — the two axes of 2D prefetch:
//!
//! 1. **Layer axis**: step time of the sparse lane with lookahead
//!    0/1/2/4 against a throttled "PCIe+SSD" store, measured for real
//!    with the background scheduler, plus the analytic pipeline-makespan
//!    prediction.
//! 2. **Expert axis**: SSD byte volume of 1D (layer-granular: every
//!    expert, every layer) vs 2D ((layer, expert)-granular: routed set +
//!    pinned hot set) staging, under uniform and Zipf-skewed routing,
//!    measured on the real hierarchical store against the
//!    `CostModel::prefetch_bytes_{1d,2d}` prediction. Under skew, 2D
//!    must move strictly fewer bytes — the paper's unbalanced-workload
//!    win.
//!
//! `cargo bench --bench ablation_prefetch`; set `SEMOE_SMOKE=1` for the
//! tier-1 smoke run (fewer steps, same assertions).

use std::time::{Duration, Instant};

use semoe::config::presets::{cluster_for_gpus, table1_model};
use semoe::metrics::Report;
use semoe::moe::LoadStats;
use semoe::prefetch::SparseScheduler;
use semoe::runtime::ParamSpec;
use semoe::sim::{pipeline_makespan, CostModel};
use semoe::storage::ssd_store::MediaPerf;
use semoe::storage::{CacheConfig, HierarchicalStore, SsdStore, StoreConfig};
use semoe::util::rng::ZipfTable;
use semoe::util::Rng;

const LAYERS: usize = 12;
const BLOCK: usize = 4096; // f32 elements per record
const IO_MS: f64 = 3.0; // per-record latency (×3 records per fetch)
const COMPUTE_MS: f64 = 10.0;

fn smoke() -> bool {
    std::env::var("SEMOE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// One-expert-per-layer store for the layer-axis (lookahead) table.
fn mk_lookahead_store(cache_layers: usize) -> HierarchicalStore {
    let specs: Vec<ParamSpec> = (0..LAYERS)
        .map(|l| ParamSpec {
            name: format!("layer{}.w1", l),
            shape: vec![1, BLOCK],
            sparse: true,
            numel: BLOCK,
        })
        .collect();
    let ssd = SsdStore::memory_backed().with_perf(MediaPerf {
        bandwidth: None,
        latency: Some(Duration::from_secs_f64(IO_MS / 1e3)),
    });
    let cfg = StoreConfig {
        cache: CacheConfig {
            capacity_bytes: cache_layers * BLOCK * 4 * 3,
            ..Default::default()
        },
        with_moments: true,
    };
    let mut s = HierarchicalStore::new(ssd, cfg, &specs, LAYERS, 1).unwrap();
    s.initialize(|_| vec![0.0; BLOCK]).unwrap();
    s
}

/// One forward sweep with `lookahead`-deep prefetch; returns wall secs.
fn sweep(lookahead: usize) -> f64 {
    let mut sched = SparseScheduler::spawn(mk_lookahead_store(2));
    let mut seqs: Vec<Option<u64>> = vec![None; LAYERS];
    for (l, s) in seqs.iter_mut().enumerate().take(lookahead.min(LAYERS - 1) + 1) {
        *s = Some(sched.request(l, 0));
    }
    let compute = Duration::from_secs_f64(COMPUTE_MS / 1e3);
    let t0 = Instant::now();
    for l in 0..LAYERS {
        let seq = seqs[l].take().unwrap_or_else(|| sched.request(l, 0));
        let _block = sched.wait(seq).unwrap();
        let nxt = l + lookahead + 1;
        if lookahead > 0 && nxt < LAYERS {
            seqs[nxt] = Some(sched.request(nxt, 0));
        }
        let t = Instant::now();
        while t.elapsed() < compute {
            std::hint::spin_loop();
        }
    }
    t0.elapsed().as_secs_f64()
}

// ---------------------------------------------------------------------
// Expert axis: 1D vs 2D byte volume under routing skew.
// ---------------------------------------------------------------------

const EXPERTS: usize = 16;
const E_LAYERS: usize = 6;
const E_BLOCK: usize = 1024; // f32 elements per expert per layer
const TOKENS: usize = 32; // routing decisions per layer per step

fn mk_expert_store() -> HierarchicalStore {
    let specs: Vec<ParamSpec> = (0..E_LAYERS)
        .map(|l| ParamSpec {
            name: format!("layer{}.w1", l),
            shape: vec![EXPERTS, E_BLOCK],
            sparse: true,
            numel: EXPERTS * E_BLOCK,
        })
        .collect();
    let cfg = StoreConfig {
        cache: CacheConfig {
            // Half the working set, so staging pressure is real.
            capacity_bytes: E_LAYERS * EXPERTS * E_BLOCK * 4 * 3 / 2,
            ..Default::default()
        },
        with_moments: true,
    };
    let mut s =
        HierarchicalStore::new(SsdStore::memory_backed(), cfg, &specs, E_LAYERS, EXPERTS)
            .unwrap();
    s.initialize(|_| vec![0.5; EXPERTS * E_BLOCK]).unwrap();
    s
}

/// Run `steps` training-step I/O patterns; returns (ssd bytes read,
/// ssd bytes written) per step. `expert_granular` = 2D; otherwise every
/// expert of every layer is staged (1D).
fn expert_sweep(expert_granular: bool, zipf_s: f64, steps: usize) -> (f64, f64) {
    let mut store = mk_expert_store();
    let zipf = ZipfTable::new(EXPERTS, zipf_s);
    let mut rng = Rng::new(42);
    let mut load: Vec<LoadStats> =
        (0..E_LAYERS).map(|_| LoadStats::new(EXPERTS, 0.5)).collect();
    for _ in 0..steps {
        // Pin the union of every layer's hot set for the whole step —
        // the policy the trainer ships (per-layer pin replacement would
        // strip protection from the other layers' hot blocks).
        if expert_granular {
            let pins: Vec<(usize, usize)> = (0..E_LAYERS)
                .flat_map(|l| load[l].hot_experts(0.5).into_iter().map(move |e| (l, e)))
                .collect();
            store.pin_hot(&pins);
        }
        for l in 0..E_LAYERS {
            // This step's routing for the layer.
            let mut counts = vec![0usize; EXPERTS];
            for _ in 0..TOKENS {
                counts[zipf.sample(&mut rng)] += 1;
            }
            let routed: Vec<usize> =
                (0..EXPERTS).filter(|&e| counts[e] > 0).collect();
            let fetch_set: Vec<usize> = if expert_granular {
                // Routed set ∪ hot set for this layer.
                let mut s = routed.clone();
                s.extend(load[l].hot_experts(0.5));
                s.sort_unstable();
                s.dedup();
                s
            } else {
                (0..EXPERTS).collect()
            };
            for &e in &fetch_set {
                let mut b = store.fetch(l, e).unwrap();
                // Dirty writeback for updated (routed) experts only —
                // 1D staging writes every expert back.
                if !expert_granular || counts[e] > 0 {
                    b.p[0] += 1.0;
                    store.update(b).unwrap();
                }
            }
            load[l].record(&counts);
        }
        store.end_step();
    }
    store.flush().unwrap();
    let st = store.ssd_stats();
    (
        st.bytes_read as f64 / steps as f64,
        st.bytes_written as f64 / steps as f64,
    )
}

fn main() {
    let steps = if smoke() { 2 } else { 6 };
    let mut rep = Report::new("ablation_prefetch");

    // ---- Layer axis: lookahead depth.
    let t = rep.table(
        &format!(
            "sparse-lane lookahead ({} layers, {:.0} ms compute, {:.0} ms I/O per layer)",
            LAYERS,
            COMPUTE_MS,
            3.0 * IO_MS
        ),
        &["lookahead", "measured ms", "predicted ms (makespan)", "vs serial"],
    );
    let serial_pred = {
        let (m, _) =
            pipeline_makespan(&[COMPUTE_MS / 1e3; LAYERS], &[3.0 * IO_MS / 1e3; LAYERS], 1);
        m
    };
    let depths: &[usize] = if smoke() { &[0, 2] } else { &[0, 1, 2, 4] };
    for &lookahead in depths {
        let measured = sweep(lookahead);
        let (pred, _) = pipeline_makespan(
            &[COMPUTE_MS / 1e3; LAYERS],
            &[3.0 * IO_MS / 1e3; LAYERS],
            lookahead + 1,
        );
        rep.row(
            t,
            vec![
                lookahead.to_string(),
                format!("{:.1}", measured * 1e3),
                format!("{:.1}", pred * 1e3),
                format!("{:.2}x", serial_pred / measured),
            ],
        );
    }

    // ---- Expert axis: 1D vs 2D bytes under uniform / Zipf routing.
    let t2 = rep.table(
        &format!(
            "1D (layer) vs 2D (expert) staging bytes/step ({} layers × {} experts, {} tokens/layer)",
            E_LAYERS, EXPERTS, TOKENS
        ),
        &["granularity", "routing", "SSD read MB/step", "SSD written MB/step", "vs 1D"],
    );
    // Analytic prediction from the cost model (same E and token count).
    let cm = CostModel::new(table1_model(EXPERTS, 8), cluster_for_gpus(8));
    let mb = |b: f64| format!("{:.2}", b / (1 << 20) as f64);
    let routings = [("uniform", 0.0), ("zipf s=1.2", 1.2)];
    // Measure each (granularity, routing) cell exactly once; 1D first so
    // its reads are available for the 2D rows' "vs 1D" ratio.
    let reads_1d: Vec<(f64, f64)> =
        routings.iter().map(|&(_, s)| expert_sweep(false, s, steps)).collect();
    let mut zipf_read = (0.0, 0.0); // (1d, 2d) for the assertion below
    for (granularity, expert_granular) in [("1D", false), ("2D", true)] {
        for (i, &(routing, s)) in routings.iter().enumerate() {
            let (rd, wr) = if expert_granular {
                expert_sweep(true, s, steps)
            } else {
                reads_1d[i]
            };
            if s > 0.0 {
                if expert_granular {
                    zipf_read.1 = rd;
                } else {
                    zipf_read.0 = rd;
                }
            }
            rep.row(
                t2,
                vec![
                    granularity.to_string(),
                    routing.to_string(),
                    mb(rd),
                    mb(wr),
                    format!("{:.2}x", rd / reads_1d[i].0.max(1.0)),
                ],
            );
        }
    }
    let predicted_frac =
        cm.expected_routed_experts(TOKENS as f64, 1.2) / EXPERTS as f64;
    rep.note(&format!(
        "cost model: E[distinct experts | zipf 1.2, {} tokens] = {:.1}/{} → 2D ≈ {:.0}% of 1D bytes",
        TOKENS,
        cm.expected_routed_experts(TOKENS as f64, 1.2),
        EXPERTS,
        predicted_frac * 100.0
    ));
    rep.note("lookahead 0 = fetch-then-compute (serial); deeper windows hide the sparse I/O \
              behind compute exactly as Algorithm 1 intends. Expert-granular staging makes the \
              streamed bytes proportional to routed load instead of model size.");
    assert!(
        zipf_read.1 < zipf_read.0,
        "2D must move strictly fewer bytes than 1D under skewed routing: {} vs {}",
        zipf_read.1,
        zipf_read.0
    );

    // ---- Planner CPU cost: contract v1 (f64 shadow recompute of every
    // layer's dense prefix) vs contract v2 (parse the kernel-emitted
    // route_expert output + full-layer repair reruns) vs contract v3
    // (same parse, but a miss re-executes only the expert tail).
    let t3 = rep.table(
        "route-planner cost per step (coordinator side, paper-scale model)",
        &["planner", "cost ms", "vs shadow"],
    );
    let shadow_s = cm.plan_secs_shadow();
    let rows = [
        ("shadow recompute (v1)", shadow_s),
        ("kernel-emitted, 0% reruns (v2)", cm.plan_secs_kernel(0.0)),
        ("kernel-emitted, 10% layer reruns (v2)", cm.plan_secs_kernel(0.10)),
        ("kernel-emitted, 10% tail reruns (v3)", cm.plan_secs_kernel_tail(0.10)),
    ];
    for (name, secs) in rows {
        rep.row(
            t3,
            vec![
                name.to_string(),
                format!("{:.2}", secs * 1e3),
                format!("{:.4}x", secs / shadow_s),
            ],
        );
    }
    rep.note("contract v2 moves routing out of the coordinator: the exact set is a kernel \
              output, so planning cost is O(tokens) parsing plus rare repair reruns instead \
              of a serialized dense-prefix recompute per layer. Contract v3 shrinks the \
              repair itself: a miss re-executes only the expert tail (dispatch → FFN → \
              combine), never the attention prefix.");
    assert!(
        cm.plan_secs_kernel(0.10) < shadow_s,
        "v2 planning (even with 10% reruns) must price below the v1 shadow recompute: {} vs {}",
        cm.plan_secs_kernel(0.10),
        shadow_s
    );

    // ---- Tail-repair ablation (contract v3): the tail re-execution
    // must undercut the full-layer re-run, and the v3 planner must beat
    // v2 whenever anything misses.
    let t4 = rep.table(
        "plan-miss repair cost (device side, per repaired layer, paper-scale model)",
        &["repair unit", "cost ms", "vs full layer"],
    );
    let layer_s = cm.rerun_secs_layer();
    let tail_s = cm.rerun_secs_tail();
    for (name, secs) in [("full layer (v2)", layer_s), ("expert tail (v3)", tail_s)] {
        rep.row(
            t4,
            vec![
                name.to_string(),
                format!("{:.3}", secs * 1e3),
                format!("{:.2}x", secs / layer_s),
            ],
        );
    }
    rep.note("the tail-vs-layer gap is the attention + router compute a contract-v3 repair \
              never spends; priced by CostModel::rerun_secs_{tail,layer}.");
    assert!(
        tail_s < layer_s,
        "tail-only repair must price below the full-layer re-run: {} vs {}",
        tail_s,
        layer_s
    );
    assert!(
        cm.plan_secs_kernel_tail(0.10) < cm.plan_secs_kernel(0.10),
        "v3 planning must beat v2 at the same miss rate: {} vs {}",
        cm.plan_secs_kernel_tail(0.10),
        cm.plan_secs_kernel(0.10)
    );
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
