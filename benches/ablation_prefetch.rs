//! Ablation — 2D-prefetch lookahead depth: step time of the sparse lane
//! with lookahead 0/1/2/4 against a throttled "PCIe+SSD" store, measured
//! for real with the background scheduler, plus the analytic
//! pipeline-makespan prediction for comparison.
//!
//! `cargo bench --bench ablation_prefetch`.

use std::time::{Duration, Instant};

use semoe::metrics::Report;
use semoe::prefetch::SparseScheduler;
use semoe::runtime::ParamSpec;
use semoe::sim::pipeline_makespan;
use semoe::storage::{CacheConfig, HierarchicalStore, SsdStore, StoreConfig};
use semoe::storage::ssd_store::MediaPerf;

const LAYERS: usize = 12;
const BLOCK: usize = 4096; // f32 elements per record
const IO_MS: f64 = 3.0; // per-record latency (×3 records per fetch)
const COMPUTE_MS: f64 = 10.0;

fn mk_store(cache_layers: usize) -> HierarchicalStore {
    let specs: Vec<ParamSpec> = (0..LAYERS)
        .map(|l| ParamSpec {
            name: format!("layer{}.w1", l),
            shape: vec![BLOCK],
            sparse: true,
            numel: BLOCK,
        })
        .collect();
    let ssd = SsdStore::memory_backed().with_perf(MediaPerf {
        bandwidth: None,
        latency: Some(Duration::from_secs_f64(IO_MS / 1e3)),
    });
    let cfg = StoreConfig {
        cache: CacheConfig {
            capacity_bytes: cache_layers * BLOCK * 4 * 3,
            ..Default::default()
        },
        with_moments: true,
    };
    let mut s = HierarchicalStore::new(ssd, cfg, &specs, LAYERS).unwrap();
    s.initialize(|_| vec![0.0; BLOCK]).unwrap();
    s
}

/// One forward sweep with `lookahead`-deep prefetch; returns wall secs.
fn sweep(lookahead: usize) -> f64 {
    let mut sched = SparseScheduler::spawn(mk_store(2));
    let mut seqs: Vec<Option<u64>> = vec![None; LAYERS];
    for l in 0..=lookahead.min(LAYERS - 1) {
        seqs[l] = Some(sched.request(l));
    }
    let compute = Duration::from_secs_f64(COMPUTE_MS / 1e3);
    let t0 = Instant::now();
    for l in 0..LAYERS {
        let seq = seqs[l].take().unwrap_or_else(|| sched.request(l));
        let _block = sched.wait(seq).unwrap();
        let nxt = l + lookahead + 1;
        if lookahead > 0 && nxt < LAYERS {
            seqs[nxt] = Some(sched.request(nxt));
        }
        let t = Instant::now();
        while t.elapsed() < compute {
            std::hint::spin_loop();
        }
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let mut rep = Report::new("ablation_prefetch");
    let t = rep.table(
        &format!(
            "sparse-lane lookahead ({} layers, {:.0} ms compute, {:.0} ms I/O per layer)",
            LAYERS,
            COMPUTE_MS,
            3.0 * IO_MS
        ),
        &["lookahead", "measured ms", "predicted ms (makespan)", "vs serial"],
    );
    let serial_pred = {
        let (m, _) = pipeline_makespan(&[COMPUTE_MS / 1e3; LAYERS], &[3.0 * IO_MS / 1e3; LAYERS], 1);
        m
    };
    for lookahead in [0usize, 1, 2, 4] {
        let measured = sweep(lookahead);
        let (pred, _) = pipeline_makespan(
            &[COMPUTE_MS / 1e3; LAYERS],
            &[3.0 * IO_MS / 1e3; LAYERS],
            lookahead + 1,
        );
        rep.row(
            t,
            vec![
                lookahead.to_string(),
                format!("{:.1}", measured * 1e3),
                format!("{:.1}", pred * 1e3),
                format!("{:.2}x", serial_pred / measured),
            ],
        );
    }
    rep.note("lookahead 0 = fetch-then-compute (serial); deeper windows hide the sparse I/O \
              behind compute exactly as Algorithm 1 intends");
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
