//! Figure 11 — MoE training time breakdown: flat vs Hierarchical
//! AlltoAll on 1/2/4 nodes (8 GPUs each) at the paper's 80.7B model.
//!
//! Part 1 prices both schedules on the Figure-7 fabric model (per-phase
//! byte/link analysis — the communication series of Fig 11).
//! Part 2 runs BOTH AlltoAll schedules for real on the in-process mesh
//! (32 ranks) and verifies they move identical data while the
//! hierarchical one sends zero cross-rail (spine) bytes.
//! Part 3 measures real expert-parallel decode (`dist::run_infer_group`,
//! deep preset): workers × {flat, hierarchical} × {Zipf, uniform}
//! prompts, with rank 0's outputs asserted bitwise invariant across
//! every configuration and the multi-worker aggregate asserted at or
//! above single-worker throughput on the skewed row.
//! Part 4 measures the dispatch-mode comparison on the same hot path:
//! {weights, tokens, auto} × worlds × {Zipf, uniform}, rank 0 asserted
//! bitwise invariant across all three lanes AND equal to single host,
//! tokens at or above weights on the large-expert/small-batch row, and
//! auto never below the slower fixed lane.
//!
//! `cargo bench --bench fig11_hierarchical_a2a` (SEMOE_SMOKE=1 for the
//! tier1 quick pass).

use semoe::comm::hierarchical::{flat_a2a, hierarchical_a2a};
use semoe::comm::{A2aStrategy, AllToAllPlan, Mesh, Topology};
use semoe::config::presets::{cluster_for_gpus, fig11_model};
use semoe::dist::{run_infer_group, zipf_prompts, DispatchMode, DistConfig};
use semoe::metrics::Report;
use semoe::runtime::ModelArtifacts;
use semoe::sim::{simulate_training, CostModel, Schedule};

fn priced(rep: &mut Report) {
    let m = fig11_model();
    let t = rep.table(
        "priced breakdown (80.7B model, 8 GPUs/node)",
        &["nodes", "flat a2a ms", "hier a2a ms", "comm gain", "flat spine MB", "hier spine MB",
          "e2e flat ms", "e2e hier ms", "e2e gain"],
    );
    for nodes in [1usize, 2, 4] {
        let cl = cluster_for_gpus(nodes * 8);
        let cm = CostModel::new(m.clone(), cl.clone());
        let c = cm.step_cost();
        let topo = Topology::new(cl.clone());
        let flat = AllToAllPlan::price(&topo, c.a2a_bytes_per_pair, A2aStrategy::Flat);
        let hier = AllToAllPlan::price(&topo, c.a2a_bytes_per_pair, A2aStrategy::Hierarchical);
        // end-to-end: full training step with each a2a schedule (other
        // SE-MoE features held fixed = the paper's ablation).
        let mut se_flat = simulate_training(&m, &cl, Schedule::SeMoe);
        let a2a_flat = flat.time * c.a2a_per_step_train;
        let a2a_hier = hier.time * c.a2a_per_step_train;
        let e2e_hier = se_flat.step_time;
        let e2e_flat = e2e_hier - a2a_hier + a2a_flat;
        se_flat.t_a2a = a2a_flat;
        rep.row(
            t,
            vec![
                nodes.to_string(),
                format!("{:.3}", flat.time * 1e3),
                format!("{:.3}", hier.time * 1e3),
                format!("{:.1}%", (1.0 - hier.time / flat.time) * 100.0),
                format!("{:.2}", flat.spine_bytes / 1e6),
                format!("{:.2}", hier.spine_bytes / 1e6),
                format!("{:.1}", e2e_flat * 1e3),
                format!("{:.1}", e2e_hier * 1e3),
                format!("{:.1}%", (1.0 - e2e_hier / e2e_flat) * 100.0),
            ],
        );
    }
    rep.note("paper (4 nodes / 32 GPUs): communication −15.5%, end-to-end −10.3%");
}

fn real_mesh(rep: &mut Report) {
    // 4 nodes × 8 gpus = 32 in-process ranks, 4 KB per pair.
    let p = 8;
    let world = 32;
    let chunk = 1024usize; // f32 elements
    let handles = Mesh::new(world);
    let joins: Vec<_> = handles
        .into_iter()
        .map(|mut h| {
            std::thread::spawn(move || {
                let rank = h.rank();
                let chunks: Vec<Vec<f32>> =
                    (0..world).map(|d| vec![(rank * world + d) as f32; chunk]).collect();
                let t0 = std::time::Instant::now();
                let flat = flat_a2a(&mut h, chunks.clone());
                let t_flat = t0.elapsed().as_secs_f64();
                let t0 = std::time::Instant::now();
                let (hier, stats) = hierarchical_a2a(&mut h, p, chunks);
                let t_hier = t0.elapsed().as_secs_f64();
                assert_eq!(flat, hier, "schedules must move identical data");
                (t_flat, t_hier, stats)
            })
        })
        .collect();
    let mut intra = 0u64;
    let mut rail = 0u64;
    let (mut tf, mut th) = (0.0f64, 0.0f64);
    let n = joins.len();
    for j in joins {
        let (a, b, s) = j.join().unwrap();
        tf += a;
        th += b;
        intra += s.intra_bytes;
        rail += s.rail_bytes;
    }
    let t = rep.table(
        "real mesh execution (32 ranks, 4 KB/pair)",
        &["schedule", "wall ms (mean)", "NVLink-class bytes/rank", "rail bytes/rank", "spine bytes"],
    );
    rep.row(
        t,
        vec![
            "flat".into(),
            format!("{:.2}", tf / n as f64 * 1e3),
            "direct".into(),
            "direct".into(),
            "crosses spine".into(),
        ],
    );
    rep.row(
        t,
        vec![
            "hierarchical".into(),
            format!("{:.2}", th / n as f64 * 1e3),
            format!("{}", intra / n as u64),
            format!("{}", rail / n as u64),
            "0 (rail-aligned)".into(),
        ],
    );
    rep.note("in-process wall times reflect memcpy, not fabric: the byte columns are the result");
}

fn real_workers(rep: &mut Report) {
    let smoke = std::env::var("SEMOE_SMOKE").is_ok();
    let preset = "deep";
    let (vocab, b) = {
        let arts = ModelArtifacts::load(preset).expect("deep artifacts (run `make artifacts`)");
        (arts.preset.vocab_size, arts.preset.batch_size)
    };
    let n_new = if smoke { 2 } else { 8 };
    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let t = rep.table(
        "measured expert-parallel decode (deep preset)",
        &["config", "workers", "a2a", "agg tokens/s", "a2a MB", "imbalance max/mean"],
    );
    let mut skew_single = 0.0f64;
    let mut skew_multi_best = 0.0f64;
    // Rank 0 decodes the same Zipf prompts in every configuration: its
    // outputs must be bitwise identical whatever the worker count or
    // AllToAll schedule — sharding moves weights, never math.
    let mut rank0_ref: Option<Vec<Vec<i32>>> = None;
    for (label, s) in [("zipf", 1.1f64), ("uniform", 0.0f64)] {
        for &w in worker_counts {
            let schedules: &[(A2aStrategy, &str, usize)] = if w == 1 {
                &[(A2aStrategy::Flat, "flat", 1)]
            } else {
                &[(A2aStrategy::Flat, "flat", 1), (A2aStrategy::Hierarchical, "hier", 2)]
            };
            for &(strategy, sname, p) in schedules {
                let cfg = DistConfig {
                    workers: w,
                    strategy,
                    ranks_per_node: p,
                    dispatch: DispatchMode::Weights,
                };
                let prompts: Vec<Vec<Vec<i32>>> = (0..w)
                    .map(|r| zipf_prompts(vocab, b, 4, s, 1000 + r as u64))
                    .collect();
                let g = run_infer_group(preset, &cfg, &prompts, n_new, 7).expect("group run");
                if label == "zipf" {
                    match &rank0_ref {
                        None => rank0_ref = Some(g.ranks[0].outputs.clone()),
                        Some(want) => assert_eq!(
                            &g.ranks[0].outputs, want,
                            "rank 0 diverged at w={} {}",
                            w, sname
                        ),
                    }
                }
                if w > 1 {
                    assert!(g.total_a2a_bytes() > 0, "multi-worker run must move blocks");
                }
                let tps = g.aggregate_tokens_per_s();
                if label == "zipf" {
                    if w == 1 {
                        skew_single = tps;
                    } else {
                        skew_multi_best = skew_multi_best.max(tps);
                    }
                }
                let imb = g.ranks.iter().map(|r| r.imbalance).fold(0.0f64, f64::max);
                rep.row(
                    t,
                    vec![
                        format!("w{} {} {}", w, sname, label),
                        w.to_string(),
                        sname.to_string(),
                        format!("{:.1}", tps),
                        format!("{:.2}", g.total_a2a_bytes() as f64 / 1e6),
                        format!("{:.2}", imb),
                    ],
                );
            }
        }
    }
    // The acceptance row: ranks decode their own prompts concurrently,
    // so the group must aggregate at least single-worker throughput on
    // skewed traffic. Smoke mode skips the timing assert (loaded CI
    // boxes make sub-second walls noisy) but keeps the bitwise one.
    if !smoke {
        assert!(
            skew_multi_best >= skew_single,
            "multi-worker aggregate fell below single worker: {:.1} < {:.1} tokens/s",
            skew_multi_best,
            skew_single
        );
    }
    rep.note("rank 0 outputs bitwise invariant across workers × schedules (asserted)");
}

fn token_dispatch(rep: &mut Report) {
    let smoke = std::env::var("SEMOE_SMOKE").is_ok();
    let preset = "deep";
    let (vocab, b) = {
        let arts = ModelArtifacts::load(preset).expect("deep artifacts (run `make artifacts`)");
        (arts.preset.vocab_size, arts.preset.batch_size)
    };
    // Short prompts + few decode steps keep the kept-token payload small
    // relative to the deep preset's expert blocks: the regime where
    // shipping activations beats shipping weights.
    let n_new = if smoke { 2 } else { 6 };
    let worlds: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let modes = [DispatchMode::Weights, DispatchMode::Tokens, DispatchMode::Auto];
    let t = rep.table(
        "token-dispatch mode comparison (deep preset)",
        &["config", "mode", "agg tokens/s", "a2a MB", "token MB", "token layers", "weight layers"],
    );
    // Rank 0 decodes the same prompts everywhere; gates and residuals are
    // applied at the home rank, so the dispatch lane must never change
    // the math — across modes, worlds, and vs a single host.
    for (label, s) in [("zipf", 1.2f64), ("uniform", 0.0f64)] {
        let solo_cfg = DistConfig { workers: 1, ..DistConfig::default() };
        let solo_prompts = vec![zipf_prompts(vocab, b, 4, s, 1000)];
        let solo = run_infer_group(preset, &solo_cfg, &solo_prompts, n_new, 7).expect("solo run");
        let want = solo.ranks[0].outputs.clone();
        for &w in worlds {
            let mut tps = [0.0f64; 3];
            for (i, &mode) in modes.iter().enumerate() {
                let cfg = DistConfig {
                    workers: w,
                    strategy: A2aStrategy::Flat,
                    ranks_per_node: 1,
                    dispatch: mode,
                };
                let prompts: Vec<Vec<Vec<i32>>> = (0..w)
                    .map(|r| zipf_prompts(vocab, b, 4, s, 1000 + r as u64))
                    .collect();
                let g = run_infer_group(preset, &cfg, &prompts, n_new, 7).expect("group run");
                assert_eq!(
                    g.ranks[0].outputs, want,
                    "rank 0 diverged from single host at w={} {} {}",
                    w,
                    label,
                    mode.as_str()
                );
                if mode == DispatchMode::Tokens {
                    let moved: u64 = g.ranks.iter().map(|r| r.dist.token_bytes).sum();
                    assert!(moved > 0, "token mode must ship activation rows");
                }
                tps[i] = g.aggregate_tokens_per_s();
                let token_mb: f64 =
                    g.ranks.iter().map(|r| r.dist.token_bytes as f64).sum::<f64>() / 1e6;
                let (tl, wl) = g.ranks.iter().fold((0u64, 0u64), |(a, c), r| {
                    (a + r.dist.token_layers, c + r.dist.weight_layers)
                });
                rep.row(
                    t,
                    vec![
                        format!("w{} {} {}", w, label, mode.as_str()),
                        mode.as_str().to_string(),
                        format!("{:.1}", tps[i]),
                        format!("{:.2}", g.total_a2a_bytes() as f64 / 1e6),
                        format!("{:.2}", token_mb),
                        tl.to_string(),
                        wl.to_string(),
                    ],
                );
            }
            // Smoke mode keeps the bitwise asserts but skips timing ones
            // (sub-second walls on loaded CI boxes are noisy).
            if !smoke {
                let (w_tps, t_tps, a_tps) = (tps[0], tps[1], tps[2]);
                if w == 2 && label == "zipf" {
                    assert!(
                        t_tps >= w_tps,
                        "token dispatch fell below weight dispatch on the \
                         large-expert/small-batch row: {:.1} < {:.1} tokens/s",
                        t_tps,
                        w_tps
                    );
                }
                assert!(
                    a_tps >= w_tps.min(t_tps),
                    "auto planner slower than both fixed lanes at w{} {}: \
                     {:.1} < min({:.1}, {:.1})",
                    w,
                    label,
                    a_tps,
                    w_tps,
                    t_tps
                );
            }
        }
    }
    rep.note("rank 0 outputs bitwise invariant across dispatch modes and vs single host (asserted)");
}

fn main() {
    let mut rep = Report::new("fig11_hierarchical_a2a");
    priced(&mut rep);
    real_mesh(&mut rep);
    real_workers(&mut rep);
    token_dispatch(&mut rep);
    println!("{}", rep.to_markdown());
    rep.save(std::path::Path::new("reports")).expect("write report");
}
